//! Shared serve-lifecycle sim harness for the integration test crates.
//!
//! Included via `#[path = "common/sim.rs"] mod sim;` from each test that
//! needs it (it is a module of those crates, not a test target of its
//! own — see the reverse-direction scan in `tools/check.py`). The
//! harness drives full request lifecycles — admit, decode, preempt,
//! resume, retire — through the *real* server machinery (`admit` /
//! `preempt` / `try_resume` / `advance_lane`) with a deterministic
//! stand-in for the model: KV rows and next tokens are pure functions
//! of (layer, position, token) and of the full sequence respectively,
//! so two stacks driven over the same prompts must produce identical
//! streams and identical final KV unless the machinery under test
//! diverges.
#![allow(
    dead_code,
    reason = "each including test crate uses a subset of the harness"
)]

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use fastkv::coordinator::decode::{advance_lane, CompactSpec, LaneAdvance};
use fastkv::coordinator::kvcache::RequestCache;
use fastkv::coordinator::paging::{KvStore, PagedArena, PagingConfig};
use fastkv::coordinator::policies::{
    chunk_spans, ChunkedPrefill, Exec, Policy, PolicyCfg, PrefillOutcome,
};
use fastkv::coordinator::scheduler::{AdmitOrder, Scheduler};
use fastkv::coordinator::server::{
    admit, preempt, try_resume, Active, Request, Resume, ServerConfig,
};
use fastkv::manifest::{Buckets, Manifest, ModelMeta};
use fastkv::metrics::Metrics;
use fastkv::obs::trace::EventKind;
use fastkv::runtime::outputs::DecodeOut;
use fastkv::tensor::HostTensor;
use fastkv::tokenizer::END;

pub fn sim_meta() -> ModelMeta {
    ModelMeta {
        vocab_size: 256,
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 2,
        tsp_layer: 1,
        window: 2,
        pool_kernel: 3,
        max_train_len: 64,
    }
}

pub fn sim_manifest(prefill_limit: usize) -> Manifest {
    Manifest {
        dir: std::path::PathBuf::from("/tmp"),
        model: sim_meta(),
        n_params: 1,
        kernel: "jnp".into(),
        buckets: Buckets {
            prefill_ns: vec![prefill_limit],
            stage1_ns: vec![prefill_limit],
            stage2_ns: vec![prefill_limit],
            chunk_c: 0,
            chunk_ns: vec![],
            pyramid_ns: vec![prefill_limit],
            decode_batches: vec![1, 2, 4],
            decode_caps: vec![64],
            sweep_n: 64,
            sweep_nt: 16,
            pallas_n: prefill_limit,
            max_gen: 16,
            block_tokens: 2,
            shard_counts: vec![],
        },
        artifacts: BTreeMap::new(),
    }
}

/// Server config over [`sim_meta`]: unbudgeted decode (the pre-budget
/// behavior); tests opt into decode budgets by mutating `policy_cfg`
/// (or via [`run_stack_budgeted`]).
pub fn sim_server_cfg(max_prompt: usize, max_new: usize) -> ServerConfig {
    ServerConfig {
        artifact_dir: std::path::PathBuf::from("/tmp"),
        policy: "sim".into(),
        policy_cfg: PolicyCfg {
            kv_rate: 1.0,
            tsp_rate: 1.0,
            sinks: 1,
            filter_layer: 0,
            use_pallas: false,
            prefill_budget: 0,
            decode_budget: 0,
            decode_window: 2,
            prefill_chunk: 0,
            prefill_decode_ratio: 1,
        },
        decode_batch: 4,
        max_new,
        max_prompt,
        order: AdmitOrder::Fcfs,
        paging: Some(PagingConfig::default()),
        obs: Default::default(),
    }
}

/// Executor stub: the sim policy never runs artifacts.
pub struct NoExec;

impl Exec for NoExec {
    fn run(
        &self,
        _name: &str,
        _inputs: Vec<fastkv::runtime::In>,
    ) -> anyhow::Result<Vec<HostTensor>> {
        anyhow::bail!("sim tests never execute artifacts")
    }
}

/// Deterministic KV row for (layer, position, token) — the "model" both
/// the sim policy's prefill and the sim decode loop share, so
/// recompute-resume rebuilds bit-identical KV and any swap bug surfaces
/// as a diverging stream.
pub fn sim_kv_row(l: usize, pos: usize, token: i32, re: usize) -> Vec<f32> {
    (0..re)
        .map(|i| {
            (l as f32) * 1000.0
                + (pos as f32) * 10.0
                + (token as f32) * 0.125
                + (i as f32) * 0.0625
        })
        .collect()
}

/// Deterministic next token from the full sequence (never END).
pub fn sim_next_token(seq: &[i32]) -> i32 {
    let mut h = 0xcbf29ce484222325u64;
    for &t in seq {
        h ^= t as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    4 + (h % 200) as i32
}

/// The sim "prefill": exactly the KV rows the sim decode loop would
/// have appended for `tokens`, plus the deterministic next token.
/// Shared by the blocking [`SimPolicy::prefill`] and the chunked
/// [`SimChunked::finish`], so the two paths are identical by
/// construction — any divergence in a chunked-vs-monolithic oracle is
/// the serve machinery's.
pub fn sim_prefill_outcome(
    man: &Manifest,
    tokens: &[i32],
    end_after: usize,
) -> PrefillOutcome {
    let m = &man.model;
    let re = m.n_kv_heads * m.head_dim;
    let mut cache = RequestCache::new(m);
    for l in 0..m.n_layers {
        let mut k = Vec::with_capacity(tokens.len() * re);
        for (pos, &t) in tokens.iter().enumerate() {
            k.extend_from_slice(&sim_kv_row(l, pos, t, re));
        }
        cache.v[l] = k.iter().map(|x| -x).collect();
        cache.k[l] = k;
        cache.lens[l] = tokens.len();
    }
    let first_token = if tokens.len() >= end_after {
        END as i32
    } else {
        sim_next_token(tokens)
    };
    PrefillOutcome {
        first_token,
        cache,
        next_pos: tokens.len(),
        final_h: Vec::new(),
        compute_tokens: tokens.len() * m.n_layers,
    }
}

/// Stand-in policy: prefill of a sequence produces exactly the KV rows
/// the sim decode loop would have appended for it, counts every call,
/// and can be told to emit END once the sequence reaches `end_after`.
/// With `cost_ns_per_token > 0` every (chunk) prefill call sleeps that
/// long per token, so serve-level benches can measure real wall-clock
/// stalls; with `prefill_chunk > 0` on the policy config it hands out
/// [`SimChunked`] drivers (and counts their chunk steps separately).
pub struct SimPolicy {
    pub calls: AtomicUsize,
    pub chunk_steps: Arc<AtomicUsize>,
    pub end_after: usize,
    pub cost_ns_per_token: u64,
}

impl SimPolicy {
    pub fn new() -> Self {
        SimPolicy {
            calls: AtomicUsize::new(0),
            chunk_steps: Arc::new(AtomicUsize::new(0)),
            end_after: usize::MAX,
            cost_ns_per_token: 0,
        }
    }

    /// Emit END once the (prompt + generated) sequence reaches `n`.
    pub fn ending_after(n: usize) -> Self {
        SimPolicy { end_after: n, ..SimPolicy::new() }
    }

    /// Charge every prefill (and every chunk) this much sleep per token.
    pub fn with_cost(ns_per_token: u64) -> Self {
        SimPolicy { cost_ns_per_token: ns_per_token, ..SimPolicy::new() }
    }

    pub fn calls(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn chunk_steps(&self) -> usize {
        self.chunk_steps.load(Ordering::Relaxed)
    }
}

fn sim_burn(ns_per_token: u64, tokens: usize) {
    if ns_per_token > 0 {
        std::thread::sleep(std::time::Duration::from_nanos(
            ns_per_token * tokens as u64,
        ));
    }
}

impl Policy for SimPolicy {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn prefill(
        &self,
        _ex: &dyn Exec,
        man: &Manifest,
        tokens: &[i32],
        _cfg: &PolicyCfg,
    ) -> anyhow::Result<PrefillOutcome> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        sim_burn(self.cost_ns_per_token, tokens.len());
        Ok(sim_prefill_outcome(man, tokens, self.end_after))
    }

    fn begin_chunked(
        &self,
        man: &Manifest,
        tokens: &[i32],
        cfg: &PolicyCfg,
    ) -> Option<anyhow::Result<Box<dyn ChunkedPrefill>>> {
        if cfg.prefill_chunk == 0 {
            return None;
        }
        let spans =
            chunk_spans(tokens.len(), cfg.prefill_chunk, man.model.window);
        Some(Ok(Box::new(SimChunked {
            tokens: tokens.to_vec(),
            spans,
            next: 0,
            end_after: self.end_after,
            cost_ns_per_token: self.cost_ns_per_token,
            steps: Arc::clone(&self.chunk_steps),
        })))
    }
}

/// The sim policy's chunked-prefill driver: pure bookkeeping over the
/// same [`sim_prefill_outcome`] the blocking path uses, so the final
/// outcome is bit-identical regardless of chunk size or park/resume
/// schedule. Each step burns the configured per-token cost and bumps
/// the shared chunk counter.
#[derive(Debug)]
pub struct SimChunked {
    tokens: Vec<i32>,
    spans: Vec<(usize, usize)>,
    next: usize,
    end_after: usize,
    cost_ns_per_token: u64,
    steps: Arc<AtomicUsize>,
}

impl ChunkedPrefill for SimChunked {
    fn total_chunks(&self) -> usize {
        self.spans.len()
    }

    fn chunks_done(&self) -> usize {
        self.next
    }

    fn next_chunk_tokens(&self) -> usize {
        self.spans.get(self.next).map(|&(_, len)| len).unwrap_or(0)
    }

    fn step(
        &mut self,
        _ex: &dyn Exec,
        _man: &Manifest,
    ) -> anyhow::Result<usize> {
        let (_, len) = *self
            .spans
            .get(self.next)
            .ok_or_else(|| anyhow::anyhow!("all chunks already run"))?;
        sim_burn(self.cost_ns_per_token, len);
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.next += 1;
        Ok(len)
    }

    fn finish(
        &mut self,
        _ex: &dyn Exec,
        man: &Manifest,
    ) -> anyhow::Result<PrefillOutcome> {
        anyhow::ensure!(
            self.next == self.spans.len(),
            "finish before all chunks ran"
        );
        Ok(sim_prefill_outcome(man, &self.tokens, self.end_after))
    }
}

/// One synthetic decode round over the active lanes, through the real
/// `advance_lane` + `Active::apply` machinery. A `CompactSpec` built
/// from `cfg.policy_cfg` is always handed down, so decode-phase budgets
/// configured on the server config act exactly as in the serving loop
/// (with `decode_budget == 0` the coarse stage is a no-op and this is
/// the historical unbudgeted round). Records a `DecodeStep` trace event
/// per advanced lane when tracing is enabled on `metrics`, as the
/// serving loop's sampled tracing does.
pub fn sim_decode_round(
    pa: &mut PagedArena,
    active: &mut [Active],
    prompts: &HashMap<u64, Vec<i32>>,
    cfg: &ServerConfig,
    metrics: &Metrics,
) {
    let m = sim_meta();
    let re = m.n_kv_heads * m.head_dim;
    let b = KvStore::slots(pa);
    let spec = CompactSpec {
        policy_cfg: &cfg.policy_cfg,
        shrink: 0.5,
        window: m.window,
        metrics: Some(metrics),
    };
    for a in active.iter_mut() {
        if a.is_done() {
            continue;
        }
        let mut k_new = HostTensor::zeros(vec![
            m.n_layers,
            b,
            m.n_kv_heads,
            m.head_dim,
        ]);
        let mut v_new = k_new.clone();
        for l in 0..m.n_layers {
            let row = sim_kv_row(l, a.pos(), a.cur(), re);
            let base = (l * b + a.slot()) * re;
            k_new.data[base..base + re].copy_from_slice(&row);
            for (i, x) in row.iter().enumerate() {
                v_new.data[base + i] = -x;
            }
        }
        let mut seq = prompts[&a.request_id()].clone();
        seq.extend_from_slice(a.tokens());
        let next = sim_next_token(&seq);
        let mut logits = HostTensor::zeros(vec![b, m.vocab_size]);
        logits.data[a.slot() * m.vocab_size + next as usize] = 1.0;
        let out = DecodeOut { logits, k_new, v_new };
        let adv = advance_lane(pa, a.slot(), &out, Some(&spec));
        assert!(
            matches!(adv, LaneAdvance::Next { .. }),
            "sim decode hit {adv:?}"
        );
        if metrics.tracer().is_enabled() {
            metrics.tracer().record(
                a.request_id(),
                a.tenant(),
                a.slot() as i32,
                EventKind::DecodeStep {
                    step: a.pos() as u32,
                    tokens_out: a.tokens().len() as u32,
                },
            );
        }
        a.apply(adv);
    }
}

/// All KV rows of a lane read through the block-table view, one
/// `K ++ V` vector per layer — slot-independent, so lanes can be
/// compared across stores that placed them differently.
pub fn lane_rows(pa: &PagedArena, slot: usize, layers: usize) -> Vec<Vec<f32>> {
    let v = pa.view();
    (0..layers)
        .map(|l| {
            let mut out = Vec::new();
            for row in 0..v.len(l, slot) {
                out.extend_from_slice(&v.k_row(l, slot, row));
            }
            for row in 0..v.len(l, slot) {
                out.extend_from_slice(&v.v_row(l, slot, row));
            }
            out
        })
        .collect()
}

pub struct StackResult {
    pub streams: HashMap<u64, Vec<i32>>,
    pub final_rows: HashMap<u64, Vec<Vec<f32>>>,
    pub policy_calls: usize,
    /// Chunk steps run by [`SimChunked`] drivers (0 on monolithic runs).
    pub chunk_steps: usize,
    pub metrics: Metrics,
}

/// Drive a full serve-shaped lifecycle — admit, decode, preempt at a
/// token-progress trigger, resume, retire — through the real server
/// functions, with swap enabled (`swap_bytes > 0`) or recompute-only.
pub fn run_stack(
    swap_bytes: usize,
    prompts: &[Vec<i32>],
    max_new: usize,
    preempt_at: usize,
) -> StackResult {
    run_stack_sharded(swap_bytes, prompts, max_new, preempt_at, 1)
}

/// [`run_stack`] over a KV-head-sharded slab (`PagingConfig::shards`).
pub fn run_stack_sharded(
    swap_bytes: usize,
    prompts: &[Vec<i32>],
    max_new: usize,
    preempt_at: usize,
    shards: usize,
) -> StackResult {
    run_stack_cfg(
        PagingConfig {
            block_tokens: 2,
            prefix_cache: false,
            swap_bytes,
            shards,
            ..Default::default()
        },
        prompts,
        max_new,
        preempt_at,
    )
}

/// [`run_stack`] with full control of the pool config (precision tiers,
/// shard counts, swap budgets).
pub fn run_stack_cfg(
    pcfg: PagingConfig,
    prompts: &[Vec<i32>],
    max_new: usize,
    preempt_at: usize,
) -> StackResult {
    run_stack_server(pcfg, prompts, preempt_at, sim_server_cfg(32, max_new))
}

/// [`run_stack_cfg`] with decode-phase budgets active: the same stack,
/// but every decode round runs the two-stage eviction configured by
/// (`decode_budget`, `decode_window`) on the server config.
pub fn run_stack_budgeted(
    pcfg: PagingConfig,
    prompts: &[Vec<i32>],
    max_new: usize,
    preempt_at: usize,
    decode_budget: usize,
    decode_window: usize,
) -> StackResult {
    let mut cfg = sim_server_cfg(32, max_new);
    cfg.policy_cfg.decode_budget = decode_budget;
    cfg.policy_cfg.decode_window = decode_window;
    run_stack_server(pcfg, prompts, preempt_at, cfg)
}

/// The fully-parameterized stack driver behind the `run_stack*` family.
pub fn run_stack_server(
    pcfg: PagingConfig,
    prompts: &[Vec<i32>],
    preempt_at: usize,
    cfg: ServerConfig,
) -> StackResult {
    let m = sim_meta();
    let man = sim_manifest(64);
    let policy = SimPolicy::new();
    let metrics = Metrics::default();
    let max_new = cfg.max_new;
    let lanes = prompts.len();
    let swap_enabled = pcfg.swap_bytes > 0;
    let mut pa = PagedArena::new(&m, lanes, 64, pcfg);
    let mut sched: Scheduler<Request> = Scheduler::new(lanes, AdmitOrder::Fcfs);
    let mut prompt_map: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut rxs = Vec::new(); // kept alive; this driver retires lanes itself
    for (i, p) in prompts.iter().enumerate() {
        let (req, rx) = Request::synthetic(i as u64, p.clone(), max_new);
        rxs.push(rx);
        prompt_map.insert(i as u64, p.clone());
        sched.enqueue(req);
    }
    let mut active: Vec<Active> = Vec::new();
    let mut preempted_once = vec![false; prompts.len()];
    let mut streams: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut final_rows: HashMap<u64, Vec<Vec<f32>>> = HashMap::new();
    let mut guard = 0;
    while streams.len() < prompts.len() {
        guard += 1;
        assert!(guard < 1_000, "sim serve loop livelocked");
        // admission / resume phase
        while sched.queue_len() > 0 {
            let req = sched.pop_next(|r| r.prompt.len()).unwrap();
            match try_resume(req, &mut pa, &metrics) {
                Resume::Restored(a) => {
                    assert!(
                        swap_enabled,
                        "swap-disabled stack must never restore"
                    );
                    active.push(a);
                }
                Resume::Busy(_) => {
                    panic!("worst-case pool reported swap-in busy")
                }
                Resume::Recompute(req) => {
                    match admit(&NoExec, &man, &policy, &cfg, req, &mut pa, &metrics)
                    {
                        Ok(a) => {
                            if a.is_done() {
                                final_rows.insert(
                                    a.request_id(),
                                    lane_rows(&pa, a.slot(), m.n_layers),
                                );
                                streams
                                    .insert(a.request_id(), a.tokens().to_vec());
                                pa.release(a.slot());
                            } else {
                                active.push(a);
                            }
                        }
                        Err(_) => panic!("worst-case pool refused admission"),
                    }
                }
            }
        }
        sim_decode_round(&mut pa, &mut active, &prompt_map, &cfg, &metrics);
        // retire before the preemption triggers so a just-finished lane
        // is never preempted (the real loop's retire pass does the same)
        let mut j = 0;
        while j < active.len() {
            if active[j].is_done() || active[j].tokens().len() >= max_new {
                let a = active.remove(j);
                final_rows
                    .insert(a.request_id(), lane_rows(&pa, a.slot(), m.n_layers));
                streams.insert(a.request_id(), a.tokens().to_vec());
                pa.release(a.slot());
            } else {
                j += 1;
            }
        }
        // token-progress preemption trigger: fires at the same point in
        // every stack, once per request
        let mut j = 0;
        while j < active.len() {
            let id = active[j].request_id() as usize;
            if !preempted_once[id] && active[j].tokens().len() >= preempt_at {
                preempted_once[id] = true;
                preempt(&mut active, j, &mut pa, &mut sched, &metrics);
            } else {
                j += 1;
            }
        }
    }
    StackResult {
        streams,
        final_rows,
        policy_calls: policy.calls(),
        chunk_steps: policy.chunk_steps(),
        metrics,
    }
}

/// One parked chunking lane in [`run_stack_chunked`]'s schedule: after
/// `after_chunks` completed chunks, park the request (completed-chunk
/// boundary) and run `decode_rounds` decode rounds before resuming.
#[derive(Clone, Copy)]
pub struct ChunkPark {
    pub after_chunks: usize,
    pub decode_rounds: usize,
}

/// Serve-shaped lifecycle with *chunked* admission: every prompt is
/// prefilled through the real `Policy::begin_chunked` → `step`* →
/// `finish` machinery, with `prefill_decode_ratio` decode rounds
/// interleaved after every chunk and an optional park/resume (via the
/// real `Request::park_chunking` / `resume_chunking` carry) at a chunk
/// boundary. The finished tail rides `Request::carry_prefill` into the
/// real `admit`, exercising the deferred-admission (pending) path — the
/// chunked run claims pool blocks only at final admission.
///
/// Against the same prompts, [`run_stack_server`] with `preempt_at >=
/// max_new` (no mid-decode preemption) must produce identical streams
/// and identical final KV rows — the chunked-vs-monolithic differential
/// oracle in `rust/tests/chunked_serve.rs`.
pub fn run_stack_chunked(
    pcfg: PagingConfig,
    prompts: &[Vec<i32>],
    park: Option<ChunkPark>,
    cfg: ServerConfig,
) -> StackResult {
    let m = sim_meta();
    let man = sim_manifest(64);
    let policy = SimPolicy::new();
    let metrics = Metrics::default();
    let max_new = cfg.max_new;
    let lanes = prompts.len();
    let mut pa = PagedArena::new(&m, lanes, 64, pcfg);
    let mut sched: Scheduler<Request> =
        Scheduler::new(lanes, AdmitOrder::Fcfs);
    let mut prompt_map: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut rxs = Vec::new(); // kept alive; this driver retires lanes itself
    for (i, p) in prompts.iter().enumerate() {
        let (req, rx) = Request::synthetic(i as u64, p.clone(), max_new);
        rxs.push(rx);
        prompt_map.insert(i as u64, p.clone());
        sched.enqueue(req);
    }
    let mut active: Vec<Active> = Vec::new();
    let mut streams: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut final_rows: HashMap<u64, Vec<Vec<f32>>> = HashMap::new();
    let retire = |active: &mut Vec<Active>,
                      pa: &mut PagedArena,
                      streams: &mut HashMap<u64, Vec<i32>>,
                      final_rows: &mut HashMap<u64, Vec<Vec<f32>>>| {
        let mut j = 0;
        while j < active.len() {
            if active[j].is_done() || active[j].tokens().len() >= max_new {
                let a = active.remove(j);
                final_rows.insert(
                    a.request_id(),
                    lane_rows(pa, a.slot(), m.n_layers),
                );
                streams.insert(a.request_id(), a.tokens().to_vec());
                pa.release(a.slot());
            } else {
                j += 1;
            }
        }
    };
    // Admit every prompt through the chunked path, decoding the already-
    // active lanes between chunks exactly as the serve loop interleaves.
    while sched.queue_len() > 0 {
        let mut req = sched.pop_next(|r| r.prompt.len()).unwrap();
        let (mut ch, mut secs) = match req.resume_chunking() {
            Some(x) => x,
            None => match policy.begin_chunked(
                &man,
                &req.prompt,
                &cfg.policy_cfg,
            ) {
                Some(Ok(ch)) => (ch, 0.0),
                Some(Err(e)) => panic!("sim begin_chunked refused: {e:#}"),
                None => panic!(
                    "run_stack_chunked needs prefill_chunk > 0 on the config"
                ),
            },
        };
        let mut parked_once = false;
        while ch.chunks_done() < ch.total_chunks() {
            if let Some(p) = park {
                if !parked_once && ch.chunks_done() == p.after_chunks {
                    // Park at the completed-chunk boundary and decode
                    // while parked; resume must re-run zero chunks.
                    parked_once = true;
                    let done = ch.chunks_done();
                    req.park_chunking(ch, secs);
                    sched.requeue_front(req);
                    for _ in 0..p.decode_rounds {
                        sim_decode_round(
                            &mut pa,
                            &mut active,
                            &prompt_map,
                            &cfg,
                            &metrics,
                        );
                        retire(
                            &mut active,
                            &mut pa,
                            &mut streams,
                            &mut final_rows,
                        );
                    }
                    req = sched.pop_next(|r| r.prompt.len()).unwrap();
                    let (c2, s2) = req
                        .resume_chunking()
                        .expect("parked chunking lane must carry its driver");
                    ch = c2;
                    secs = s2;
                    assert_eq!(
                        ch.chunks_done(),
                        done,
                        "resume must start at the parked chunk boundary"
                    );
                }
            }
            let t0 = std::time::Instant::now();
            ch.step(&NoExec, &man).unwrap();
            secs += t0.elapsed().as_secs_f64();
            for _ in 0..cfg.policy_cfg.prefill_decode_ratio {
                sim_decode_round(
                    &mut pa,
                    &mut active,
                    &prompt_map,
                    &cfg,
                    &metrics,
                );
                retire(&mut active, &mut pa, &mut streams, &mut final_rows);
            }
        }
        let outcome = ch.finish(&NoExec, &man).unwrap();
        req.carry_prefill(outcome, secs);
        match admit(&NoExec, &man, &policy, &cfg, req, &mut pa, &metrics) {
            Ok(a) => {
                if a.is_done() {
                    final_rows.insert(
                        a.request_id(),
                        lane_rows(&pa, a.slot(), m.n_layers),
                    );
                    streams.insert(a.request_id(), a.tokens().to_vec());
                    pa.release(a.slot());
                } else {
                    active.push(a);
                }
            }
            Err(_) => panic!("worst-case pool refused chunked admission"),
        }
    }
    // Drain the remaining decode work.
    let mut guard = 0;
    while streams.len() < prompts.len() {
        guard += 1;
        assert!(guard < 1_000, "chunked sim serve loop livelocked");
        sim_decode_round(&mut pa, &mut active, &prompt_map, &cfg, &metrics);
        retire(&mut active, &mut pa, &mut streams, &mut final_rows);
    }
    StackResult {
        streams,
        final_rows,
        policy_calls: policy.calls(),
        chunk_steps: policy.chunk_steps(),
        metrics,
    }
}
