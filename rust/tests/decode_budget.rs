//! Decode-phase KV budget suite — the differential oracle pinning the
//! two-stage eviction machinery (`DecodeBudget` over `PagedArena`).
//!
//! The strongest checks are *differential*: a budgeted serve stack is
//! driven in lockstep against the unbudgeted stack over the same
//! prompts and must produce identical token streams — bit-identical
//! final KV when the budget is slack, bounded divergence with every
//! protected region (sink rows, FastKV prefill-selected rows, sliding
//! decode window) intact when the budget is tight. Hard invariants ride
//! along: the coarse release path never double-frees (pool accounting
//! reconciles after every release), Σ per-tenant held blocks equals the
//! pool's in-use gauge, and a budgeted lane's resident block count is
//! O(budget) regardless of how many tokens it generates — the
//! bounded-growth regression the unbudgeted baseline pins from the
//! other side.

use fastkv::coordinator::kvcache::RequestCache;
use fastkv::coordinator::paging::{
    AppendResult, DecodeBudget, DecodeView, KvStore, PagedArena,
    PagingConfig,
};
use fastkv::manifest::ModelMeta;
use fastkv::metrics::names;
use fastkv::tensor::HostTensor;
use fastkv::util::rng::Rng;

#[path = "common/sim.rs"]
mod sim;
use sim::*;

fn cases(n: usize) -> impl Iterator<Item = (u64, Rng)> {
    (0..n as u64).map(|seed| (seed, Rng::new(seed)))
}

/// A prompt cache over [`sim_meta`] whose rows follow the sim model
/// ([`sim_kv_row`]), so store-level tests agree with the stack harness.
fn prompt_cache(m: &ModelMeta, tokens: &[i32]) -> RequestCache {
    let re = m.n_kv_heads * m.head_dim;
    let mut rc = RequestCache::new(m);
    for l in 0..m.n_layers {
        let mut k = Vec::with_capacity(tokens.len() * re);
        for (pos, &t) in tokens.iter().enumerate() {
            k.extend_from_slice(&sim_kv_row(l, pos, t, re));
        }
        rc.v[l] = k.iter().map(|x| -x).collect();
        rc.k[l] = k;
        rc.lens[l] = tokens.len();
    }
    rc
}

/// One decode-step append tensor pair for a single lane of a `b`-lane
/// store, rows from the sim model at `pos` for `token`.
fn step_for(
    m: &ModelMeta,
    b: usize,
    slot: usize,
    pos: usize,
    token: i32,
) -> (HostTensor, HostTensor) {
    let re = m.n_kv_heads * m.head_dim;
    let mut k = HostTensor::zeros(vec![m.n_layers, b, m.n_kv_heads, m.head_dim]);
    let mut v = k.clone();
    for l in 0..m.n_layers {
        let row = sim_kv_row(l, pos, token, re);
        let base = (l * b + slot) * re;
        k.data[base..base + re].copy_from_slice(&row);
        for (i, x) in row.iter().enumerate() {
            v.data[base + i] = -x;
        }
    }
    (k, v)
}

/// K rows of a lane/layer read through a [`DecodeView`], flattened.
fn view_k_rows(v: &DecodeView<'_>, l: usize, slot: usize) -> Vec<f32> {
    let mut out = Vec::new();
    for row in 0..v.len(l, slot) {
        out.extend_from_slice(&v.k_row(l, slot, row));
    }
    out
}

/// Physical block ids of `(l, slot)` in a view's flat table, in order.
fn view_table(v: &DecodeView<'_>, l: usize, slot: usize, b: usize) -> Vec<i32> {
    let base = (l * b + slot) * v.max_blocks;
    v.tables[base..base + v.max_blocks]
        .iter()
        .copied()
        .filter(|&id| id >= 0)
        .collect()
}

fn assert_pool_reconciles(pa: &PagedArena, what: &str) {
    let ps = pa.pool_stats();
    assert_eq!(
        ps.blocks_in_use + ps.blocks_cached + ps.blocks_free,
        ps.blocks_total,
        "pool accounting broken after {what}"
    );
    let held: usize = pa.tenant_stats().iter().map(|t| t.held_blocks).sum();
    assert_eq!(
        held, ps.blocks_in_use,
        "Σ tenant held blocks vs pool in-use after {what}"
    );
}

// ------------------------------------------------- lockstep differentials

#[test]
fn slack_budget_stack_is_bit_identical_to_unbudgeted() {
    // A decode budget far above anything the stack generates must be a
    // perfect no-op: same token streams, bit-identical final KV, zero
    // blocks evicted or pruned. This is the safety half of the oracle —
    // turning the feature on cannot perturb a workload it never binds.
    let prompts: Vec<Vec<i32>> =
        vec![vec![10, 11, 12], vec![20, 21, 22, 23], vec![30, 31]];
    let max_new = 5;
    let pcfg = || PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 0,
        ..Default::default()
    };
    let base = run_stack_cfg(pcfg(), &prompts, max_new, 2);
    let slack = run_stack_budgeted(pcfg(), &prompts, max_new, 2, 64, 2);
    for id in 0..prompts.len() as u64 {
        assert_eq!(
            slack.streams[&id], base.streams[&id],
            "token stream diverged for request {id} under a slack budget"
        );
        assert_eq!(slack.streams[&id].len(), max_new);
        assert_eq!(
            slack.final_rows[&id], base.final_rows[&id],
            "final KV diverged for request {id} under a slack budget"
        );
    }
    assert_eq!(
        slack.metrics.counter(names::DECODE_BLOCKS_EVICTED),
        0,
        "slack budget must release nothing"
    );
    assert_eq!(base.metrics.counter(names::DECODE_BLOCKS_EVICTED), 0);
}

#[test]
fn tight_budget_stack_bounds_kv_and_preserves_protected_rows() {
    // The divergence-accounting half: with a budget the generation
    // actually exceeds, the stack still produces the same token stream
    // (the sim model is KV-independent, so any difference would mean
    // the lifecycle machinery itself broke), the evicted counter is
    // live through the `advance_lane` coarse stage, resident generated
    // KV is bounded well below the unbudgeted footprint, and the
    // protected regions — prefill-selected prefix and sliding window —
    // survive verbatim.
    let m = sim_meta();
    let re = m.n_kv_heads * m.head_dim;
    let prompts: Vec<Vec<i32>> =
        vec![vec![10, 11, 12, 13], vec![20, 21, 22, 23]];
    let max_new = 14;
    let (budget, window) = (2usize, 2usize);
    let pcfg = || PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 0,
        ..Default::default()
    };
    // preempt_at past max_new: no preemption, so the prefill boundary
    // stays at the original prompt rows for the whole run.
    let base = run_stack_cfg(pcfg(), &prompts, max_new, 100);
    let tight = run_stack_budgeted(pcfg(), &prompts, max_new, 100, budget, window);
    assert!(
        tight.metrics.counter(names::DECODE_BLOCKS_EVICTED) > 0,
        "a binding budget must release generated blocks"
    );
    // fine = max(budget, window) = 2, coarse = 2 * fine = 4; post-append
    // enforcement may overshoot by at most one block before the next
    // step trims it again.
    let coarse_rows = 4;
    let slack_rows = coarse_rows + window + 2;
    for id in 0..prompts.len() as u64 {
        assert_eq!(
            tight.streams[&id], base.streams[&id],
            "token stream diverged for request {id}"
        );
        let boundary = prompts[id as usize].len();
        for l in 0..m.n_layers {
            let b_rows = &base.final_rows[&id][l];
            let t_rows = &tight.final_rows[&id][l];
            let len_b = b_rows.len() / (2 * re);
            let len_t = t_rows.len() / (2 * re);
            // the admit-time first token is not appended, so the lane
            // holds max_new - 1 generated rows
            assert_eq!(
                len_b,
                boundary + max_new - 1,
                "unbudgeted keeps all rows"
            );
            assert!(
                len_t < len_b,
                "request {id} layer {l}: budget released nothing"
            );
            assert!(
                len_t - boundary <= slack_rows,
                "request {id} layer {l}: {} generated rows resident \
                 under a coarse budget of {coarse_rows}",
                len_t - boundary
            );
            // Prefill-selected prefix: never evicted, content intact.
            assert_eq!(
                t_rows[..boundary * re],
                b_rows[..boundary * re],
                "request {id} layer {l}: prefill K rows diverged"
            );
            // Sliding window: the trailing rows match the unbudgeted
            // stack's trailing rows (K plane; V mirrors K in the sim).
            let k_t = &t_rows[..len_t * re];
            let k_b = &b_rows[..len_b * re];
            assert_eq!(
                k_t[(len_t - window) * re..],
                k_b[(len_b - window) * re..],
                "request {id} layer {l}: window rows diverged"
            );
        }
    }
}

// --------------------------------------------------- randomized invariants

#[test]
fn prop_coarse_release_never_touches_protected_rows() {
    // Randomized interleavings of admit / append / compact / release /
    // swap-out / swap-in with the coarse stage enforced throughout:
    // sink rows, prefill-selected rows, and the sliding window survive
    // every release verbatim; pool accounting reconciles (Σ held ==
    // blocks_in_use — a double-free through the release path would
    // break the identity); teardown returns every block.
    for (seed, mut rng) in cases(40) {
        let m = sim_meta();
        let re = m.n_kv_heads * m.head_dim;
        let lanes = 3;
        let pcfg = PagingConfig {
            block_tokens: 2,
            prefix_cache: rng.chance(0.3),
            swap_bytes: if rng.chance(0.5) { 1 << 20 } else { 0 },
            ..Default::default()
        };
        let swap_on = pcfg.swap_bytes > 0;
        let mut pa = PagedArena::new(&m, lanes, 64, pcfg);
        let budget = DecodeBudget {
            fine_rows: rng.range(2, 6),
            coarse_rows: rng.range(4, 10),
            window: rng.range(1, 3),
            sinks: rng.range(0, 2),
        };
        let mut live: Vec<usize> = Vec::new();
        let mut next_tok = 100 + seed as i32;
        for _ in 0..rng.range(1, lanes) {
            let plen = rng.range(1, 6);
            let toks: Vec<i32> = (0..plen as i32).map(|t| 4 + t).collect();
            let slot = KvStore::admit(&mut pa, &prompt_cache(&m, &toks))
                .expect("worst-case pool admits");
            live.push(slot);
        }
        for op in 0..rng.range(10, 40) {
            if live.is_empty() {
                let toks = vec![4, 5, 6];
                live.push(
                    KvStore::admit(&mut pa, &prompt_cache(&m, &toks)).unwrap(),
                );
            }
            let slot = live[rng.below(live.len())];
            match rng.below(6) {
                // append a generated row, then enforce — the serve
                // loop's post-append coarse stage
                0 | 1 | 2 => {
                    let pos = KvStore::layer_lens(&pa, slot)[0];
                    let (k, v) = step_for(&m, lanes, slot, pos, next_tok);
                    next_tok += 1;
                    if !matches!(
                        KvStore::append(&mut pa, slot, &k, &v),
                        AppendResult::Ok
                    ) {
                        continue;
                    }
                    let before = lane_rows(&pa, slot, m.n_layers);
                    let bounds = pa.prefill_boundary(slot);
                    let released =
                        pa.enforce_decode_budget(slot, &budget);
                    assert_pool_reconciles(&pa, "coarse release");
                    let after = lane_rows(&pa, slot, m.n_layers);
                    let lens = KvStore::layer_lens(&pa, slot);
                    for l in 0..m.n_layers {
                        let len_b = before[l].len() / (2 * re);
                        let len_a = lens[l];
                        assert!(len_a <= len_b, "release grew a lane");
                        let prot = bounds[l].max(budget.sinks).min(len_a);
                        // protected prefix: content at the same rows
                        assert_eq!(
                            after[l][..prot * re],
                            before[l][..prot * re],
                            "seed {seed} op {op} layer {l}: sink/prefill \
                             K rows changed"
                        );
                        // sliding window: trailing rows intact
                        let w = budget.window.min(len_a);
                        assert_eq!(
                            after[l][(len_a - w) * re..len_a * re],
                            before[l][(len_b - w) * re..len_b * re],
                            "seed {seed} op {op} layer {l}: window \
                             K rows changed"
                        );
                        // never release into the protected regions
                        assert!(
                            len_a >= prot + w.min(len_a - prot),
                            "seed {seed}: lane shrunk into protection"
                        );
                    }
                    if released == 0 {
                        assert_eq!(before, after, "no-op release mutated KV");
                    }
                }
                // block-granular compaction (FastKV decoupled stage)
                3 => {
                    let lens = KvStore::layer_lens(&pa, slot);
                    let keep: Vec<Vec<usize>> = lens
                        .iter()
                        .map(|&n| {
                            let k = rng.range(1, n.max(1));
                            rng.distinct_sorted(k.min(n), n)
                        })
                        .collect();
                    KvStore::compact(&mut pa, slot, &keep);
                    assert_pool_reconciles(&pa, "compact");
                }
                // release + re-admit
                4 => {
                    assert!(pa.release(slot));
                    live.retain(|&s| s != slot);
                    assert_pool_reconciles(&pa, "release");
                }
                // swap round-trip (when the arena has a swap budget)
                _ => {
                    if !swap_on {
                        continue;
                    }
                    let Some(h) = pa.swap_out(slot) else { continue };
                    live.retain(|&s| s != slot);
                    assert_pool_reconciles(&pa, "swap-out");
                    match pa.swap_in(h) {
                        fastkv::coordinator::paging::SwapIn::Restored(s) => {
                            live.push(s);
                        }
                        other => panic!("seed {seed}: swap-in {other:?}"),
                    }
                    assert_pool_reconciles(&pa, "swap-in");
                }
            }
        }
        for slot in live {
            assert!(pa.release(slot));
        }
        let ps = pa.pool_stats();
        assert_eq!(
            ps.blocks_in_use, 0,
            "seed {seed}: teardown leaked blocks"
        );
        assert_pool_reconciles(&pa, "teardown");
    }
}

// ------------------------------------------------- bounded growth pinning

#[test]
fn budgeted_lane_holds_bounded_blocks_forever() {
    // The headline capacity win: a lane generating far past its staging
    // capacity keeps appending under a decode budget because the coarse
    // stage releases cold blocks as fast as new ones fill — resident
    // blocks stay O(budget). The unbudgeted baseline pins the old
    // behavior from the other side: append stops dead at capacity.
    let m = sim_meta();
    let cap = 16;
    let prompt = vec![10, 11, 12, 13];
    let pcfg = || PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 0,
        ..Default::default()
    };
    let budget = DecodeBudget {
        fine_rows: 2,
        coarse_rows: 4,
        window: 2,
        sinks: 1,
    };

    // Unbudgeted baseline: 4 prompt rows + 12 appends fill the lane;
    // the 13th append reports CapacityExhausted (the seed's silent
    // truncation point).
    let mut pa = PagedArena::new(&m, 1, cap, pcfg());
    let slot = KvStore::admit(&mut pa, &prompt_cache(&m, &prompt)).unwrap();
    let mut stopped_at = None;
    for i in 0..100 {
        let pos = KvStore::layer_lens(&pa, slot)[0];
        let (k, v) = step_for(&m, 1, slot, pos, 50 + i as i32);
        match KvStore::append(&mut pa, slot, &k, &v) {
            AppendResult::Ok => {}
            AppendResult::CapacityExhausted => {
                stopped_at = Some(i);
                break;
            }
            AppendResult::PoolExhausted => panic!("pool sized for the lane"),
        }
    }
    assert_eq!(
        stopped_at,
        Some(cap - prompt.len()),
        "unbudgeted lane must stop exactly at staging capacity"
    );

    // Budgeted lane: 100 appends — ~6x the staging capacity — all Ok,
    // with the resident block count flat at O(budget) throughout.
    let mut pa = PagedArena::new(&m, 1, cap, pcfg());
    let slot = KvStore::admit(&mut pa, &prompt_cache(&m, &prompt)).unwrap();
    let bt = 2;
    // per layer: prefill blocks + coarse survivors + window + one
    // in-flight block of post-enforcement overshoot
    let per_layer = prompt.len().div_ceil(bt)
        + budget.coarse_rows.div_ceil(bt)
        + budget.window.div_ceil(bt)
        + 1;
    let bound = m.n_layers * per_layer;
    let mut peak = 0usize;
    for i in 0..100 {
        let pos = KvStore::layer_lens(&pa, slot)[0];
        let (k, v) = step_for(&m, 1, slot, pos, 50 + i as i32);
        assert!(
            matches!(
                KvStore::append(&mut pa, slot, &k, &v),
                AppendResult::Ok
            ),
            "budgeted lane refused append {i}"
        );
        pa.enforce_decode_budget(slot, &budget);
        peak = peak.max(KvStore::held_blocks(&pa, slot));
        assert_pool_reconciles(&pa, "budgeted append");
    }
    assert!(
        peak <= bound,
        "budgeted lane peaked at {peak} blocks (bound {bound})"
    );
    assert!(
        pa.pool_stats().decode_region_blocks <= bound,
        "decode-region gauge exceeds the budget bound"
    );
    let lens = KvStore::layer_lens(&pa, slot);
    for (l, &len) in lens.iter().enumerate() {
        assert!(
            len >= prompt.len() + budget.window,
            "layer {l}: protected rows missing after long generation"
        );
        assert!(len < cap, "layer {l}: lane filled despite the budget");
    }
}

// ----------------------------------------------------- fine-stage pruning

#[test]
fn fine_stage_prunes_view_without_touching_residency() {
    // The per-step attention view drops the coldest generated blocks to
    // the fine budget while the store itself keeps every row: pruning
    // is pure table surgery (an ordered subsequence handed to the same
    // gather artifacts), so the unbudgeted view taken before and after
    // must be identical.
    let m = sim_meta();
    let re = m.n_kv_heads * m.head_dim;
    let prompt = vec![10, 11, 12, 13];
    let pcfg = PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 0,
        ..Default::default()
    };
    let mut pa = PagedArena::new(&m, 1, 64, pcfg);
    let slot = KvStore::admit(&mut pa, &prompt_cache(&m, &prompt)).unwrap();
    for i in 0..10 {
        let pos = KvStore::layer_lens(&pa, slot)[0];
        let (k, v) = step_for(&m, 1, slot, pos, 60 + i as i32);
        assert!(matches!(
            KvStore::append(&mut pa, slot, &k, &v),
            AppendResult::Ok
        ));
    }
    // coarse_rows high enough that residency is untouched; fine binds
    let budget = DecodeBudget {
        fine_rows: 4,
        coarse_rows: 100,
        window: 2,
        sinks: 1,
    };
    assert_eq!(pa.enforce_decode_budget(slot, &budget), 0);

    let boundary = prompt.len();
    let full_before = view_k_rows(&pa.view(), 0, slot);
    let pruned = pa.view_budgeted(Some(&budget));
    // gen = 14 - 4 = 10 > fine 4: drop ceil((10-4)/2) = 3 blocks/layer
    assert_eq!(pruned.pruned_blocks, 3 * m.n_layers);
    assert_eq!(pa.view().pruned_blocks, 0, "unbudgeted view never prunes");
    assert!(pruned.max_blocks <= pa.view().max_blocks);
    for l in 0..m.n_layers {
        let full = pa.view();
        assert_eq!(pruned.len(l, slot), full.len(l, slot) - 3 * 2);
        // pruned table is an ordered subsequence of the full table
        let ft = view_table(&full, l, slot, 1);
        let pt = view_table(&pruned, l, slot, 1);
        let mut fi = 0;
        for id in &pt {
            while fi < ft.len() && ft[fi] != *id {
                fi += 1;
            }
            assert!(
                fi < ft.len(),
                "layer {l}: pruned table is not a subsequence"
            );
            fi += 1;
        }
        // prefill prefix attended verbatim
        let pk = view_k_rows(&pruned, l, slot);
        let fk = view_k_rows(&full, l, slot);
        assert_eq!(pk[..boundary * re], fk[..boundary * re]);
        // window tail attended verbatim
        let (pl, fl) = (pruned.len(l, slot), full.len(l, slot));
        assert_eq!(
            pk[(pl - budget.window) * re..],
            fk[(fl - budget.window) * re..],
            "layer {l}: window rows missing from the pruned view"
        );
    }
    // pruning left residency alone: the unbudgeted view still reads
    // every original row
    assert_eq!(view_k_rows(&pa.view(), 0, slot), full_before);
    assert_eq!(KvStore::layer_lens(&pa, slot), vec![14; m.n_layers]);
}

// ----------------------------------------------- recompute-resume ratchet

#[test]
fn budgeted_stack_survives_preemption_and_resume() {
    // Budgets composed with the preemption machinery: a budgeted stack
    // that preempts and recompute-resumes every request still retires
    // everything with the same token streams as the unbudgeted stack,
    // and the resumed lanes' conservative prefill ratchet (restored KV
    // counts as prefill) never trips the eviction invariants.
    let prompts: Vec<Vec<i32>> =
        vec![vec![10, 11, 12], vec![20, 21, 22, 23], vec![30, 31]];
    let max_new = 10;
    let mk = |swap: usize| PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: swap,
        ..Default::default()
    };
    for swap in [0usize, 1 << 20] {
        let base = run_stack_cfg(mk(swap), &prompts, max_new, 3);
        let tight = run_stack_budgeted(mk(swap), &prompts, max_new, 3, 2, 2);
        for id in 0..prompts.len() as u64 {
            assert_eq!(
                tight.streams[&id], base.streams[&id],
                "swap={swap}: stream diverged for request {id}"
            );
            assert_eq!(tight.streams[&id].len(), max_new);
        }
    }
}
