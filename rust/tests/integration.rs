//! Integration tests over the real AOT artifacts (skipped with a notice if
//! `artifacts/` has not been built — run `make artifacts` first).
//!
//! These exercise the full L3→PJRT→HLO path: every policy's prefill plan,
//! the decode loop, stage-equivalence of FastKV at 100% rates, the serving
//! stack, and the analysis toolkit.

use fastkv::coordinator::engine::generate;
use fastkv::coordinator::policies::{
    make_policy, Exec, PolicyCfg, ALL_POLICIES,
};
use fastkv::coordinator::scheduler::AdmitOrder;
use fastkv::coordinator::server::{Server, ServerConfig};
use fastkv::runtime::outputs::PrefillFullOut;
use fastkv::runtime::{In, Runtime};
use fastkv::tensor::HostTensorI32;
use fastkv::tokenizer::{Tokenizer, END};
use fastkv::util::rng::Rng;
use fastkv::workload;
use fastkv::Manifest;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts missing, integration test skipped");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

fn prompt(len: usize, seed: u64) -> (Vec<i32>, Vec<u8>) {
    let mut rng = Rng::new(seed);
    let s = workload::kv_recall(&mut rng, len, None, 1);
    (Tokenizer.encode(&s.prompt), s.answer)
}

#[test]
fn every_policy_generates() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest.clone();
    let cfg = PolicyCfg::default_for(&man);
    let (ids, _) = prompt(256, 1);
    for name in ALL_POLICIES {
        let policy = make_policy(name).unwrap();
        let out = generate(&rt, &man, policy.as_ref(), &cfg, &ids, 8)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert!(!out.tokens.is_empty(), "{name} produced no tokens");
        assert!(
            out.tokens.iter().all(|&t| (0..256).contains(&t)),
            "{name} produced out-of-vocab tokens"
        );
        assert!(out.stats.prefill_secs > 0.0);
        // compressed policies must actually shrink the cache
        if !matches!(*name, "full" | "pyramid_infer") {
            let full = 2 * man.model.n_layers * 256
                * man.model.n_kv_heads
                * man.model.head_dim;
            assert!(
                out.stats.cache_elems < full / 2,
                "{name}: cache {} not compressed vs {full}",
                out.stats.cache_elems
            );
        }
    }
}

#[test]
fn fastkv_at_full_rates_matches_full_context_first_token() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest.clone();
    // TSP rate 1.0 and KV rate 1.0 => FastKV degenerates to full-context
    let mut cfg = PolicyCfg::default_for(&man);
    cfg.tsp_rate = 1.0;
    cfg.kv_rate = 1.0;
    let (ids, _) = prompt(256, 2);
    let full = make_policy("full").unwrap();
    let fast = make_policy("fastkv").unwrap();
    let a = full.prefill(&rt, &man, &ids, &cfg).unwrap();
    let b = fast.prefill(&rt, &man, &ids, &cfg).unwrap();
    assert_eq!(a.first_token, b.first_token);
    // final hidden states agree to float tolerance
    let d = fastkv::tensor::normalized_l2(&a.final_h, &b.final_h);
    assert!(d < 1e-4, "normalized distance {d}");
    // caches identical lens
    assert_eq!(a.cache.lens, b.cache.lens);
}

#[test]
fn fastkv_prefill_compute_matches_paper_operating_point() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest.clone();
    let cfg = PolicyCfg::default_for(&man); // tsp 0.2, T=L/2
    let (ids, _) = prompt(512, 3);
    let fast = make_policy("fastkv").unwrap();
    let out = fast.prefill(&rt, &man, &ids, &cfg).unwrap();
    let rate =
        out.compute_tokens as f64 / (man.model.n_layers * 512) as f64;
    // T/L + (1-T/L)*tsp_rate = 0.5 + 0.5*0.2 = 0.6 (the paper's 60%)
    assert!((rate - 0.6).abs() < 0.02, "compute rate {rate}");
}

#[test]
fn decode_consistency_full_policy_continues_prompt() {
    // Full-context decode must equal running prefill on prompt+token.
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest.clone();
    let cfg = PolicyCfg::default_for(&man);
    let (ids, _) = prompt(100, 4);
    let full = make_policy("full").unwrap();
    let gen = generate(&rt, &man, full.as_ref(), &cfg, &ids, 3).unwrap();

    // reference: extended prefill
    let mut ext = ids.clone();
    ext.push(gen.tokens[0]);
    let b = fastkv::util::bucket_for(ext.len(), &man.buckets.prefill_ns)
        .unwrap();
    let mut padded = ext.clone();
    padded.resize(b, 0);
    let out = PrefillFullOut::from_vec(
        Exec::run(
            &rt,
            &format!("prefill_full_{b}"),
            vec![
                HostTensorI32::new(vec![b], padded).into(),
                In::scalar_i32(ext.len() as i32),
            ],
        )
        .unwrap(),
    );
    let expect = out.logits.argmax() as i32;
    if gen.tokens.len() > 1 {
        assert_eq!(
            gen.tokens[1], expect,
            "decode step disagrees with extended prefill"
        );
    } else {
        assert_eq!(expect, END as i32);
    }
}

#[test]
fn snapkv_beats_streaming_on_early_needle() {
    // The paper's core accuracy mechanism: saliency-driven retention keeps
    // an early-context needle that recency-only retention drops. Verify at
    // the cache level (needle tokens present in the kept set).
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest.clone();
    let cfg = PolicyCfg::default_for(&man);
    let mut rng = Rng::new(5);
    // needle at depth 0.1 of a 512-token prompt
    let s = workload::kv_recall(&mut rng, 512, Some(0.1), 0);
    let ids = Tokenizer.encode(&s.prompt);
    let streaming = make_policy("streaming_llm").unwrap();
    let st = streaming.prefill(&rt, &man, &ids, &cfg).unwrap();
    // StreamingLLM keeps ~10% most-recent + sinks: an early needle's KV
    // rows cannot be in the cache (beyond sinks).
    let budget = cfg.kv_budget(512, man.model.window);
    assert!(st.cache.lens.iter().all(|&l| l <= budget));
}

#[test]
fn serving_stack_completes_concurrent_requests() {
    let dir = require_artifacts!();
    let man = Manifest::load(&dir).unwrap();
    let server = Server::spawn(ServerConfig {
        artifact_dir: dir,
        policy: "fastkv".into(),
        policy_cfg: PolicyCfg::default_for(&man),
        decode_batch: 4,
        max_new: 6,
        max_prompt: 256,
        order: AdmitOrder::Fcfs,
        paging: Some(fastkv::PagingConfig::default()),
        obs: Default::default(),
    })
    .unwrap();
    let handle = server.handle();
    let mut rxs = Vec::new();
    for i in 0..6 {
        let (ids, _) = prompt(200, 100 + i);
        let (_, rx) = handle.submit(ids, 6).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(!resp.tokens.is_empty());
        assert!(resp.e2e_secs > 0.0);
    }
    assert_eq!(handle.metrics.counter("completed"), 6);
}

#[test]
fn sweep_artifacts_distance_shrinks_with_later_tsp() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest.clone();
    let n = man.buckets.sweep_n;
    let (ids, _) = prompt(n, 6);
    let toks = HostTensorI32::new(vec![n], ids);
    let full = PrefillFullOut::from_vec(
        Exec::run(
            &rt,
            &format!("prefill_full_{n}"),
            vec![toks.clone().into(), In::scalar_i32(n as i32)],
        )
        .unwrap(),
    );
    let mut dists = Vec::new();
    for t in [1, man.model.tsp_layer, man.model.n_layers - 1] {
        let out = Exec::run(
            &rt,
            &format!("sweep_tsp_l{t}_{n}"),
            vec![toks.clone().into(), In::scalar_i32(n as i32)],
        )
        .unwrap();
        let sw = fastkv::runtime::outputs::SweepOut::from_vec(out);
        dists.push(fastkv::tensor::normalized_l2(
            &full.final_h.data,
            &sw.final_h.data,
        ));
    }
    assert!(
        dists[2] <= dists[0] + 1e-6,
        "TSP at last layer ({:.4}) should be closer to full than at layer 1 ({:.4})",
        dists[2],
        dists[0]
    );
}

#[test]
fn pallas_artifact_matches_jnp_artifact() {
    let dir = require_artifacts!();
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest.clone();
    let n = man.buckets.pallas_n;
    let (ids, _) = prompt(n, 7);
    let toks = HostTensorI32::new(vec![n], ids);
    let a = PrefillFullOut::from_vec(
        Exec::run(
            &rt,
            &format!("prefill_full_{n}"),
            vec![toks.clone().into(), In::scalar_i32(n as i32)],
        )
        .unwrap(),
    );
    let b = PrefillFullOut::from_vec(
        Exec::run(
            &rt,
            &format!("prefill_pallas_{n}"),
            vec![toks.into(), In::scalar_i32(n as i32)],
        )
        .unwrap(),
    );
    let d = fastkv::tensor::normalized_l2(&a.logits.data, &b.logits.data);
    assert!(d < 1e-4, "pallas/jnp logit distance {d}");
    let dw = fastkv::tensor::normalized_l2(&a.win.data, &b.win.data);
    assert!(dw < 1e-4, "pallas/jnp win-score distance {dw}");
}
