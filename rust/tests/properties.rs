//! Property-based tests on coordinator invariants (hand-rolled generator
//! loop — proptest is not vendored; each property runs over hundreds of
//! randomized cases with printable failure seeds).

use fastkv::coordinator::kvcache::{BatchArena, RequestCache};
use fastkv::coordinator::scheduler::{Action, AdmitOrder, Scheduler};
use fastkv::coordinator::selection as sel;
use fastkv::eval::{char_f1, edit_sim, levenshtein};
use fastkv::manifest::ModelMeta;
use fastkv::tensor::HostTensor;
use fastkv::util::json::Value;
use fastkv::util::rng::Rng;

fn cases(n: usize) -> impl Iterator<Item = (u64, Rng)> {
    (0..n as u64).map(|seed| (seed, Rng::new(seed)))
}

// ---------------------------------------------------------------- selection

#[test]
fn prop_topk_selected_are_the_best() {
    for (seed, mut rng) in cases(300) {
        let n = rng.range(1, 64);
        let n_valid = rng.range(1, n);
        let k = rng.range(1, n);
        let scores: Vec<f32> =
            (0..n).map(|_| rng.f64() as f32).collect();
        let sel = sel::top_k_with_forced(&scores, n_valid, k, &[]);
        let expect = k.min(n_valid);
        assert_eq!(sel.len(), expect, "seed {seed}");
        assert!(sel.windows(2).all(|w| w[0] < w[1]), "sorted, seed {seed}");
        assert!(sel.iter().all(|&i| i < n_valid), "valid, seed {seed}");
        // every selected score >= every unselected valid score
        let min_sel = sel
            .iter()
            .map(|&i| scores[i])
            .fold(f32::INFINITY, f32::min);
        for i in 0..n_valid {
            if !sel.contains(&i) {
                assert!(
                    scores[i] <= min_sel + 1e-6,
                    "seed {seed}: unselected {i} beats selected"
                );
            }
        }
    }
}

#[test]
fn prop_forced_indices_always_kept() {
    for (seed, mut rng) in cases(300) {
        let n = rng.range(4, 64);
        let n_valid = rng.range(2, n);
        let k = rng.range(1, n_valid);
        let window = rng.range(1, k);
        let scores: Vec<f32> =
            (0..n).map(|_| rng.f64() as f32).collect();
        let forced = sel::window_indices(n_valid, window);
        let s = sel::top_k_with_forced(&scores, n_valid, k, &forced);
        for f in &forced {
            assert!(
                s.contains(f) || s.len() == k && forced.len() > k,
                "seed {seed}: window idx {f} dropped (sel {s:?})"
            );
        }
    }
}

#[test]
fn prop_maxpool_dominates_input_and_is_monotone() {
    for (seed, mut rng) in cases(200) {
        let n = rng.range(1, 100);
        let kernel = *[1usize, 3, 5, 7].get(rng.below(4)).unwrap();
        let x: Vec<f32> =
            (0..n).map(|_| (rng.f64() * 10.0 - 5.0) as f32).collect();
        let y = sel::maxpool1d(&x, kernel);
        assert_eq!(y.len(), n);
        for i in 0..n {
            assert!(y[i] >= x[i], "seed {seed}: pool below input at {i}");
        }
        let global = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(y.iter().cloned().fold(f32::NEG_INFINITY, f32::max) <= global);
    }
}

#[test]
fn prop_groupwise_budget_exact() {
    for (seed, mut rng) in cases(200) {
        let kv = rng.range(1, 4);
        let groups = rng.range(1, 3);
        let h = kv * groups;
        let n = rng.range(8, 96);
        let n_valid = rng.range(4, n);
        let k = rng.range(1, n_valid);
        let win: Vec<f32> =
            (0..h * n).map(|_| rng.f64() as f32).collect();
        let sets = sel::select_kv_groupwise(&win, h, n, n_valid, kv, k, 2, 3);
        assert_eq!(sets.len(), kv, "seed {seed}");
        for s in &sets {
            assert_eq!(s.len(), k.min(n_valid), "seed {seed}");
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
    }
}

// ---------------------------------------------------------------- kvcache

fn meta(rng: &mut Rng) -> ModelMeta {
    ModelMeta {
        vocab_size: 256,
        d_model: 16,
        n_layers: rng.range(1, 4),
        n_heads: 2,
        n_kv_heads: rng.range(1, 2),
        head_dim: rng.range(2, 8),
        tsp_layer: 1,
        window: 4,
        pool_kernel: 3,
        max_train_len: 64,
    }
}

#[test]
fn prop_cache_roundtrip_through_arena() {
    // fill RequestCache with tagged rows -> load into arena -> rows land at
    // the right [layer, slot, row] offsets.
    for (seed, mut rng) in cases(150) {
        let m = meta(&mut rng);
        let n = rng.range(8, 32);
        let tag = |l: usize, t: usize, e: usize| {
            (l * 10_000 + t * 10 + e) as f32
        };
        let re = m.n_kv_heads * m.head_dim;
        let mut data = Vec::new();
        for l in 0..m.n_layers {
            for t in 0..n {
                for e in 0..re {
                    data.push(tag(l, t, e));
                }
            }
        }
        let k_src = HostTensor::new(
            vec![m.n_layers, n, m.n_kv_heads, m.head_dim],
            data.clone(),
        );
        let v_src = k_src.clone();
        let mut rc = RequestCache::new(&m);
        let mut sels = Vec::new();
        for l in 0..m.n_layers {
            let len = rng.range(1, n);
            let s = rng.distinct_sorted(len, n);
            rc.fill_layer(l, &k_src, &v_src, l, &s);
            sels.push(s);
        }
        let cap = n + 4;
        let b = rng.range(1, 4);
        let mut arena = BatchArena::new(&m, b, cap);
        let slot = arena.alloc_slot().unwrap();
        arena.load(slot, &rc);
        for l in 0..m.n_layers {
            assert_eq!(
                arena.lens[l * b + slot] as usize,
                sels[l].len(),
                "seed {seed}"
            );
            for (row, &t) in sels[l].iter().enumerate() {
                let base = ((l * b + slot) * cap + row) * re;
                for e in 0..re {
                    assert_eq!(
                        arena.k.data[base + e],
                        tag(l, t, e),
                        "seed {seed} l{l} row{row}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_arena_slots_never_interfere() {
    for (seed, mut rng) in cases(100) {
        let m = meta(&mut rng);
        let b = rng.range(2, 4);
        let cap = rng.range(4, 16);
        let mut arena = BatchArena::new(&m, b, cap);
        let s0 = arena.alloc_slot().unwrap();
        let s1 = arena.alloc_slot().unwrap();
        let mk = |v: f32| {
            HostTensor::new(
                vec![m.n_layers, b, m.n_kv_heads, m.head_dim],
                vec![v; m.n_layers * b * m.n_kv_heads * m.head_dim],
            )
        };
        let a = mk(1.0);
        let bb = mk(2.0);
        let n0 = rng.range(1, cap);
        for _ in 0..n0 {
            arena.append(s0, &a, &a);
        }
        arena.free_slot(s1);
        let s1b = arena.alloc_slot().unwrap();
        assert_eq!(s1, s1b, "seed {seed}");
        arena.append(s1b, &bb, &bb);
        // slot 0 rows must still be exactly 1.0
        let re = m.n_kv_heads * m.head_dim;
        for l in 0..m.n_layers {
            let len0 = arena.lens[l * b + s0] as usize;
            assert_eq!(len0, n0.min(cap), "seed {seed}");
            let base = ((l * b + s0) * cap) * re;
            for e in 0..len0 * re {
                assert_eq!(arena.k.data[base + e], 1.0, "seed {seed}");
            }
        }
    }
}

// ---------------------------------------------------------------- scheduler

#[test]
fn prop_scheduler_never_starves_and_never_overfills() {
    for (seed, mut rng) in cases(200) {
        let max_active = rng.range(1, 4);
        let order = if rng.chance(0.5) {
            AdmitOrder::Fcfs
        } else {
            AdmitOrder::ShortestFirst
        };
        let mut s: Scheduler<usize> = Scheduler::new(max_active, order);
        let mut active = 0usize;
        let mut completed = 0usize;
        let total = rng.range(1, 20);
        let mut submitted = 0usize;
        let mut steps = 0;
        while completed < total {
            steps += 1;
            assert!(steps < 10_000, "seed {seed}: livelock");
            if submitted < total && rng.chance(0.3) {
                s.enqueue(rng.range(1, 100));
                submitted += 1;
            }
            match s.next_action(active) {
                Action::Prefill => {
                    let _ = s.pop_next(|&x| x).unwrap();
                    active += 1;
                    assert!(active <= max_active, "seed {seed}");
                }
                Action::DecodeStep => {
                    if rng.chance(0.4) && active > 0 {
                        active -= 1;
                        completed += 1;
                    }
                }
                Action::Idle => {
                    assert_eq!(active, 0, "seed {seed}");
                    if submitted < total {
                        s.enqueue(rng.range(1, 100));
                        submitted += 1;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------- metrics & json

#[test]
fn prop_scoring_metrics_bounded_and_reflexive() {
    for (seed, mut rng) in cases(300) {
        let la = rng.range(0, 12);
        let lb = rng.range(0, 12);
        let a: Vec<u8> =
            (0..la).map(|_| b'a' + rng.below(4) as u8).collect();
        let b: Vec<u8> =
            (0..lb).map(|_| b'a' + rng.below(4) as u8).collect();
        for f in [char_f1, edit_sim] {
            let v = f(&a, &b);
            assert!((0.0..=1.0).contains(&v), "seed {seed}: {v}");
            assert!((f(&a, &a) - 1.0).abs() < 1e-9, "seed {seed}");
            assert!(
                (f(&a, &b) - f(&b, &a)).abs() < 1e-9,
                "seed {seed}: symmetric"
            );
        }
        // levenshtein triangle inequality against empty
        assert!(levenshtein(&a, &b) <= a.len().max(b.len()));
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Num((rng.below(100_000) as f64) / 8.0),
            3 => Value::Str(
                (0..rng.below(8))
                    .map(|_| {
                        *[
                            'a', 'b', '"', '\\', '\n', '€', 'x', '\t',
                        ]
                        .get(rng.below(8))
                        .unwrap()
                    })
                    .collect(),
            ),
            4 => Value::Arr(
                (0..rng.below(4))
                    .map(|_| gen_value(rng, depth + 1))
                    .collect(),
            ),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below(4) {
                    m.insert(format!("k{i}"), gen_value(rng, depth + 1));
                }
                Value::Obj(m)
            }
        }
    }
    for (seed, mut rng) in cases(300) {
        let v = gen_value(&mut rng, 0);
        let text = v.to_string();
        let v2 = Value::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e} in {text}"));
        assert_eq!(v, v2, "seed {seed}: {text}");
    }
}
