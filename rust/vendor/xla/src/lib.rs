//! Stub of the `xla` PJRT bindings used by `fastkv::runtime`.
//!
//! The build image for this repo carries no native XLA/PJRT toolchain, so
//! this crate provides the exact API surface the runtime layer links
//! against and fails *at runtime* (not compile time) with a clear message
//! when a PJRT client is requested. Everything host-side — policies,
//! selection, the paged KV-cache subsystem, scheduling, workloads — is
//! independent of this stub; artifact-driven tests and benches detect the
//! missing backend (or missing `artifacts/` dir) and skip themselves.
//!
//! Swapping in the real bindings is a Cargo.toml change only: the method
//! names and signatures here mirror the `PjRtClient::cpu()` /
//! `HloModuleProto::from_text_file` / `compile` / `execute_b` pattern.
//!
//! Input shapes this surface must cover (the runtime validates them
//! against the manifest, the stub only has to accept the element types):
//!  * dense decode (`decode_{B}x{C}`): f32 `[L, B, C, KV, hd]` caches plus
//!    i32 `[B]` tokens/positions and i32 `[L, B]` lens;
//!  * block-table decode (`decode_paged_{B}x{C}`): f32 slab planes
//!    `[NB, bt, KV, hd]` (device-pinned across steps via
//!    `Runtime::run_with_pinned`), i32 block tables `[L, B, MB]`, and the
//!    same token/position/lens inputs. `on_device_size_in_bytes` feeds the
//!    runtime's pinned-memory gauge and mirrors the PJRT C API
//!    (`PJRT_Buffer_OnDeviceSizeInBytes`).

use std::fmt;
use std::path::Path;

/// Error type with the same `{e}` Display ergonomics as the real bindings.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: this build vendors the stub `xla` crate \
         (no native XLA/PJRT toolchain in the image); host-side paths are \
         fully functional, artifact execution requires the real bindings"
            .to_string(),
    )
}

/// Sealed-ish marker for element types PJRT buffers/literals carry here.
pub trait Element: Copy + 'static {}
impl Element for f32 {}
impl Element for i32 {}
impl Element for i64 {}
impl Element for u8 {}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real bindings construct a CPU client; the stub always errors.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: Element>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }

    /// Device bytes backing this buffer (PJRT_Buffer_OnDeviceSizeInBytes).
    /// Callers fall back to the host-side size when unavailable.
    pub fn on_device_size_in_bytes(&self) -> Result<usize> {
        Err(unavailable())
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT backend unavailable"));
    }
}
