//! Minimal, API-compatible subset of the `anyhow` crate, vendored because
//! this build environment has no crates.io registry access.
//!
//! Supported surface (exactly what the fastkv crate uses):
//!  * `anyhow::Error` — context-chain error; `{e}` prints the outermost
//!    message, `{e:#}` prints the full `outer: ...: root` chain, `{e:?}`
//!    prints the message plus a `Caused by:` list.
//!  * `anyhow::Result<T>` (with default error type).
//!  * `anyhow!`, `bail!`, `ensure!` macros (format-string forms).
//!  * `Context` extension trait: `.context(..)` / `.with_context(..)` on
//!    `Result<T, E: Into<Error>>` (covers std errors *and* `anyhow::Error`)
//!    and on `Option<T>`.
//!  * `From<E>` for every `E: std::error::Error + Send + Sync + 'static`,
//!    so `?` converts io/channel/parse errors as the real crate does.

use std::convert::Infallible;
use std::fmt;

/// Context-chain error: `msgs[0]` is the outermost (most recent) context,
/// the last entry is the root cause.
pub struct Error {
    msgs: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msgs: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by the `Context` trait).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.msgs.insert(0, context.to_string());
        self
    }

    /// The root-cause message (innermost of the chain).
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the chain from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, matching real anyhow.
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))?;
        if self.msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, m) in self.msgs[1..].iter().enumerate() {
                write!(f, "\n    {i}: {m}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`; that is what
// makes this blanket conversion coherent (same trick as the real crate).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => {
                let err: Error = e.into();
                Err(err.context(context))
            }
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => {
                let err: Error = e.into();
                Err(err.context(f()))
            }
        }
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{:#}", f().unwrap_err()), "gone");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let r: std::result::Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: gone");
        // context on an already-anyhow error stacks
        let e2: Error = anyhow!("root");
        let r2: Result<u32> = Err(e2);
        let e2 = r2.context("outer").unwrap_err();
        assert_eq!(format!("{e2:#}"), "outer: root");
    }

    #[test]
    fn macros() {
        fn b() -> Result<()> {
            bail!("bad {}", 7)
        }
        assert_eq!(b().unwrap_err().to_string(), "bad 7");
        fn e(x: usize) -> Result<()> {
            ensure!(x > 2, "x too small: {x}");
            Ok(())
        }
        assert!(e(3).is_ok());
        assert_eq!(e(1).unwrap_err().to_string(), "x too small: 1");
        let name = "art";
        let err = anyhow!("compiling {name}: oops");
        assert_eq!(err.to_string(), "compiling art: oops");
    }
}
