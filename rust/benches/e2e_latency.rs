//! Fig. 4 / Fig. 9 bench: end-to-end latency breakdown (prefill vs decode)
//! per method per context length.
//!
//! Run: cargo bench --bench e2e_latency
//!      (env FASTKV_BENCH_QUICK=1 for a fast smoke pass,
//!       FASTKV_BENCH_LENS=256,512 to override lengths)

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::bench;
use fastkv::coordinator::policies::{make_policy, PolicyCfg};
use fastkv::generate;
use fastkv::runtime::Runtime;
use fastkv::tokenizer::Tokenizer;
use fastkv::util::rng::Rng;
use fastkv::workload;

fn main() {
    let rt = match Runtime::new(&fastkv::Manifest::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench (no artifacts): {e}");
            return;
        }
    };
    let man = rt.manifest.clone();
    let cfg = PolicyCfg::default_for(&man);
    let tok = Tokenizer;
    let lens: Vec<usize> = std::env::var("FASTKV_BENCH_LENS")
        .map(|v| v.split(',').map(|s| s.parse().unwrap()).collect())
        .unwrap_or_else(|_| {
            // default: cap at 1024 to bound `cargo bench` wall time; the
            // 2048 point is produced by `fastkv bench --lens ...,2048`.
            man.buckets
                .stage1_ns
                .iter()
                .copied()
                .filter(|&n| n <= 1024)
                .collect()
        });
    let gen = if bench_util::quick() { 8 } else { 32 };

    println!("\n=== e2e_latency (Fig 4/9): gen {gen} tokens ===");
    for &len in &lens {
        for m in ["full", "streaming_llm", "snapkv", "gemfilter", "pyramid_infer", "fastkv"] {
            let policy = make_policy(m).unwrap();
            let mut rng = Rng::new(3);
            let s = workload::kv_recall(&mut rng, len, None, 1);
            let ids = tok.encode(&s.prompt);
            // one untimed call to compile artifacts
            if let Err(e) = generate(&rt, &man, policy.as_ref(), &cfg, &ids, 2)
            {
                println!("{m:>14}@{len}: unsupported ({e})");
                continue;
            }
            let mut prefill_acc = 0.0;
            let mut decode_acc = 0.0;
            let mut count = 0usize;
            bench(&format!("{m}@{len}"), 1, 3, || {
                let out = generate(
                    &rt, &man, policy.as_ref(), &cfg, &ids, gen,
                )
                .unwrap();
                prefill_acc += out.stats.prefill_secs;
                decode_acc += out.stats.decode_secs;
                count += 1;
            });
            println!(
                "{:>46} prefill {:8.2} ms | decode {:8.2} ms",
                "",
                prefill_acc * 1e3 / count as f64,
                decode_acc * 1e3 / count as f64
            );
        }
    }
}
