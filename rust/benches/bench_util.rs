//! Shared mini bench harness (criterion is not vendored): warmup + timed
//! reps with mean/std/min, honoring --quick via env FASTKV_BENCH_QUICK.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
    pub reps: usize,
}

pub fn quick() -> bool {
    std::env::var("FASTKV_BENCH_QUICK").is_ok()
}

pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> BenchResult {
    let reps = if quick() { reps.min(3).max(1) } else { reps };
    for _ in 0..warmup.min(if quick() { 1 } else { warmup }) {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        mean_ms: mean,
        std_ms: var.sqrt(),
        min_ms: min,
        reps,
    };
    println!(
        "{:44} {:10.2} ms ±{:7.2}  (min {:.2}, n={})",
        r.name, r.mean_ms, r.std_ms, r.min_ms, r.reps
    );
    r
}

#[allow(
    dead_code,
    reason = "this file doubles as a #[path]-included module of every \
              bench; the main() only exists to satisfy rustc when a \
              tool compiles it standalone"
)]
fn main() {}
