//! Paged KV-cache micro-benchmarks: admit (with and without prefix
//! sharing), per-step append, staging materialization, block compaction,
//! the decode-step input-prep comparison (dense staged bridge vs
//! block-table `DecodeView`) across staging capacities and pool sizes at
//! fixed retained KV, the preemption-resume comparison (swap-to-host
//! restore vs the re-prefill floor), and a 2-tenant contention scenario
//! (quotas off vs on) — PJRT-independent, with block-pool stats reported
//! next to the timings. The swap and tenant comparisons additionally
//! write `BENCH_paging_swap.json` / `BENCH_paging_tenants.json`
//! summaries so CI captures the trajectories; the sharded-slab,
//! quantization, and decode-budget long-generation scenarios likewise
//! emit `BENCH_paging_shard.json` / `BENCH_paging_quant.json` /
//! `BENCH_paging_decode.json`, and the chunked-prefill interleaving
//! scenario (one long admission over active decode lanes, blocking vs
//! chunked) emits `BENCH_serve_chunked.json`.
//!
//! Run: cargo bench --bench paging   (FASTKV_BENCH_QUICK=1 for a smoke pass)

#[path = "bench_util.rs"]
mod bench_util;
#[path = "../tests/common/sim.rs"]
mod sim;

use bench_util::bench;
use fastkv::coordinator::kvcache::{BatchArena, RequestCache};
use fastkv::coordinator::paging::{
    AppendResult, DecodeBudget, KvStore, PagedArena, PagingConfig,
};
use fastkv::manifest::ModelMeta;
use fastkv::tensor::HostTensor;
use fastkv::util::rng::Rng;
use fastkv::PolicyCfg;
use fastkv::{TenantId, TenantQuota};

fn meta() -> ModelMeta {
    ModelMeta {
        vocab_size: 256,
        d_model: 96,
        n_layers: 8,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 24,
        tsp_layer: 4,
        window: 8,
        pool_kernel: 7,
        max_train_len: 512,
    }
}

fn cache(m: &ModelMeta, seed: u64, len: usize) -> RequestCache {
    let re = m.n_kv_heads * m.head_dim;
    let mut rc = RequestCache::new(m);
    for l in 0..m.n_layers {
        let keep = if l < m.tsp_layer { len } else { len / 2 };
        let mut rng = Rng::new(seed * 100 + l as u64);
        rc.k[l] = (0..keep * re).map(|_| rng.f64() as f32).collect();
        rc.v[l] = (0..keep * re).map(|_| rng.f64() as f32).collect();
        rc.lens[l] = keep;
    }
    rc
}

fn main() {
    let m = meta();
    let b = 4;
    let len = 2048;
    let cap = len + 64;
    let cfg = PagingConfig::default();

    println!("\n=== paging (block pool, prefix cache, staging) ===");

    // admit: distinct prompts (all misses) vs shared prompt (all hits)
    let distinct: Vec<RequestCache> =
        (0..b as u64).map(|i| cache(&m, i, len)).collect();
    let mut pa = PagedArena::new(&m, b, cap, cfg.clone());
    bench("PagedArena admit x4 distinct (2048 tok)", 2, 20, || {
        let slots: Vec<usize> = distinct
            .iter()
            .map(|rc| KvStore::admit(&mut pa, rc).unwrap())
            .collect();
        for s in slots {
            pa.release(s);
        }
    });
    let ps = pa.pool_stats();
    println!(
        "{:>46} pool: {} blocks total, hit rate {:.1}%",
        "",
        ps.blocks_total,
        100.0 * ps.prefix_hit_rate()
    );

    let shared = cache(&m, 7, len);
    let mut pa = PagedArena::new(&m, b, cap, cfg.clone());
    // warm the prefix cache once so steady-state admits are all hits
    let s0 = KvStore::admit(&mut pa, &shared).unwrap();
    bench("PagedArena admit x3 shared-prefix (2048 tok)", 2, 20, || {
        let slots: Vec<usize> = (1..b)
            .map(|_| KvStore::admit(&mut pa, &shared).unwrap())
            .collect();
        for s in slots {
            pa.release(s);
        }
    });
    let ps = pa.pool_stats();
    println!(
        "{:>46} pool: {}/{} blocks in use, hit rate {:.1}%, evictions {}",
        "",
        ps.blocks_in_use,
        ps.blocks_total,
        100.0 * ps.prefix_hit_rate(),
        ps.evictions
    );
    pa.release(s0);

    // flat-arena load for comparison
    let mut flat = BatchArena::new(&m, b, cap);
    bench("BatchArena admit x4 (2048 tok, flat copy)", 2, 20, || {
        let slots: Vec<usize> = distinct
            .iter()
            .map(|rc| KvStore::admit(&mut flat, rc).unwrap())
            .collect();
        for s in slots {
            KvStore::release(&mut flat, s);
        }
    });

    // per-step append + staging
    let mut pa = PagedArena::new(&m, b, cap, cfg.clone());
    let slots: Vec<usize> = distinct
        .iter()
        .map(|rc| KvStore::admit(&mut pa, rc).unwrap())
        .collect();
    let step = HostTensor::zeros(vec![
        m.n_layers,
        b,
        m.n_kv_heads,
        m.head_dim,
    ]);
    bench("PagedArena append x4 lanes", 3, 200, || {
        for &s in &slots {
            let _ = KvStore::append(&mut pa, s, &step, &step);
        }
    });
    bench("PagedArena stage (4 x 2112 cap)", 3, 50, || {
        let st = KvStore::stage(&pa);
        std::hint::black_box(&st.k.data[0]);
    });

    // block compaction driven by policy keep-sets
    let policy_cfg = PolicyCfg {
        kv_rate: 0.1,
        tsp_rate: 0.2,
        sinks: 4,
        filter_layer: m.tsp_layer - 1,
        use_pallas: false,
        prefill_budget: 0,
        decode_budget: 0,
        decode_window: m.window,
        prefill_chunk: 0,
        prefill_decode_ratio: 1,
    };
    bench("compact to 50% (policy keep-sets)", 1, 20, || {
        let mut pa = PagedArena::new(&m, 1, cap, cfg.clone());
        let slot = KvStore::admit(&mut pa, &distinct[0]).unwrap();
        let lens = KvStore::layer_lens(&pa, slot);
        let keep = policy_cfg.compaction_keep(&lens, 0.5, m.window);
        let released = KvStore::compact(&mut pa, slot, &keep);
        std::hint::black_box(released);
    });
    let ps = pa.pool_stats();
    println!(
        "{:>46} final pool: {}/{} in use, cow {}, alloc failures {}",
        "",
        ps.blocks_in_use,
        ps.blocks_total,
        ps.cow_copies,
        ps.alloc_failures
    );

    // --------------------------------------------------------------------
    // Decode-step input prep: the dense staged bridge clones a full
    // [L, B, C, KV, hd] tensor pair per generated token (cost grows with
    // the staging capacity C — the dense layout's "pool"), while the
    // block-table plan copies only table indices + lens and borrows the
    // slab in place (cost follows the retained KV, independent of both C
    // and the block-pool size).
    println!("\n=== decode-step input prep: staged bridge vs block tables ===");
    println!("    (fixed retained KV: {} tokens/layer, batch {b})", 256);
    let retained = 256usize;
    let mut staged_ms = Vec::new();
    let mut view_ms = Vec::new();
    for cap in [320usize, 576, 1088, 2112] {
        let dense_cfg = PagingConfig {
            dense_staging: true,
            ..PagingConfig::default()
        };
        let mut dense = PagedArena::new(&m, b, cap, dense_cfg);
        let mut paged = PagedArena::new(&m, b, cap, PagingConfig::default());
        for i in 0..b as u64 {
            let rc = cache(&m, 40 + i, retained);
            KvStore::admit(&mut dense, &rc).unwrap();
            KvStore::admit(&mut paged, &rc).unwrap();
        }
        let r1 = bench(
            &format!("staged step (cap {cap}, retained {retained})"),
            2,
            30,
            || {
                let st = KvStore::stage(&dense);
                std::hint::black_box(&st.k.data[0]);
            },
        );
        let r2 = bench(
            &format!("block-table step (cap {cap}, retained {retained})"),
            2,
            30,
            || {
                let view = paged.view();
                let tables = view.tables_tensor(view.max_blocks);
                let lens = view.lens_tensor();
                std::hint::black_box((&tables.data[0], &lens.data[0]));
            },
        );
        staged_ms.push(r1.mean_ms);
        view_ms.push(r2.mean_ms);
    }
    // Pool-size sweep at fixed cap + retained KV: the block-table plan
    // must not get more expensive as the pool grows.
    let cap = 2112usize;
    let bt = PagingConfig::default().block_tokens;
    for shrink in [4usize, 2, 1] {
        let worst = m.n_layers * b * ((cap + bt - 1) / bt);
        let blocks = (worst / shrink)
            .max(m.n_layers * b * ((retained + bt - 1) / bt) + m.n_layers);
        let cfg = PagingConfig {
            num_blocks: Some(blocks),
            ..PagingConfig::default()
        };
        let mut paged = PagedArena::new(&m, b, cap, cfg);
        for i in 0..b as u64 {
            let rc = cache(&m, 60 + i, retained);
            KvStore::admit(&mut paged, &rc).unwrap();
        }
        bench(
            &format!("block-table step (pool {blocks} blocks)"),
            2,
            30,
            || {
                let view = paged.view();
                let tables = view.tables_tensor(view.max_blocks);
                let lens = view.lens_tensor();
                std::hint::black_box((&tables.data[0], &lens.data[0]));
            },
        );
        // Honest accounting: when the device-pinned slab is STALE (it is
        // after every append on the current pure-AOT ABI — in-place device
        // update needs PJRT buffer donation, a ROADMAP follow-up), the
        // paged path additionally materializes the padded slab. That part
        // does scale with the pool; it is measured separately so the plan
        // numbers above don't overstate the win.
        bench(
            &format!("  + slab materialize if stale (pool {blocks})"),
            2,
            10,
            || {
                let view = paged.view();
                let (sk, sv) = view.slab_tensors(blocks);
                std::hint::black_box((&sk.data[0], &sv.data[0]));
            },
        );
    }
    let grow_staged = staged_ms.last().unwrap() / staged_ms.first().unwrap().max(1e-9);
    let grow_view = view_ms.last().unwrap() / view_ms.first().unwrap().max(1e-9);
    println!(
        "{:>46} staged cost grew {grow_staged:.1}x from cap 320 -> 2112; \
         block-table plan {grow_view:.1}x (slab upload amortized by \
         version pinning; per-append device update awaits donation)",
        ""
    );

    // --------------------------------------------------------------------
    // Preemption resume: swap-to-host restore vs the re-prefill floor.
    // Swap serializes the lane's blocks to host and restores them into
    // fresh blocks; recompute-resume at minimum rebuilds the compressed
    // cache and re-admits it (measured below as "re-admit floor") and in
    // reality additionally re-runs the whole policy prefill on device —
    // so the gap reported here is a strict lower bound on the win.
    println!("\n=== preemption resume: swap-to-host vs re-prefill floor ===");
    use fastkv::SwapIn;
    use std::time::Instant;
    let resume_len = 2048usize;
    let swap_cfg = PagingConfig {
        prefix_cache: false, // symmetric: neither path gets block reuse
        swap_bytes: 1 << 30,
        ..PagingConfig::default()
    };
    let rc = cache(&m, 11, resume_len);
    let mut pa = PagedArena::new(&m, b, resume_len + 64, swap_cfg.clone());
    let mut slot = KvStore::admit(&mut pa, &rc).unwrap();
    let reps = if bench_util::quick() { 3 } else { 30 };
    // warm
    let h = pa.swap_out(slot).expect("swap budget");
    slot = match pa.swap_in(h) {
        SwapIn::Restored(s) => s,
        other => panic!("swap-in failed in bench: {other:?}"),
    };
    let mut out_ms = Vec::with_capacity(reps);
    let mut in_ms = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let h = pa.swap_out(slot).expect("swap budget");
        out_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        let t1 = Instant::now();
        slot = match pa.swap_in(h) {
            SwapIn::Restored(s) => s,
            other => panic!("swap-in failed in bench: {other:?}"),
        };
        in_ms.push(t1.elapsed().as_secs_f64() * 1e3);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (swap_out_ms, swap_in_ms) = (mean(&out_ms), mean(&in_ms));
    println!(
        "{:44} {swap_out_ms:10.2} ms (out) + {swap_in_ms:.2} ms (in), n={reps}",
        format!("swap roundtrip ({resume_len} tok)")
    );
    let outs = pa.swap_stats().swap_outs; // sanity: every rep swapped
    assert!(outs as usize >= reps);

    let mut pa2 =
        PagedArena::new(&m, b, resume_len + 64, swap_cfg.clone());
    let mut slot2 = KvStore::admit(&mut pa2, &rc).unwrap();
    let r_readmit = bench(
        &format!("re-admit floor of recompute ({resume_len} tok)"),
        2,
        reps,
        || {
            assert!(pa2.release(slot2));
            slot2 = KvStore::admit(&mut pa2, &rc).unwrap();
        },
    );
    println!(
        "{:>46} (+ the policy prefill itself on the real recompute path)",
        ""
    );

    let entry_bytes =
        rc.total_elems() * std::mem::size_of::<f32>();
    let json = format!(
        "{{\n  \"resume_tokens\": {resume_len},\n  \"layers\": {},\n  \
         \"entry_bytes\": {entry_bytes},\n  \"swap_out_ms\": {swap_out_ms:.4},\n  \
         \"swap_in_ms\": {swap_in_ms:.4},\n  \
         \"readmit_floor_ms\": {:.4},\n  \
         \"swap_in_vs_readmit\": {:.3},\n  \"reps\": {reps}\n}}\n",
        m.n_layers,
        r_readmit.mean_ms,
        swap_in_ms / r_readmit.mean_ms.max(1e-9),
    );
    std::fs::write("BENCH_paging_swap.json", &json)
        .expect("write BENCH_paging_swap.json");
    println!("\nwrote BENCH_paging_swap.json:\n{json}");

    // --------------------------------------------------------------------
    // Sharded slabs: input prep (scratch-buffered vs allocating) and
    // upload amplification. The upload model is the decode planner's own
    // staleness logic (`decode::stale_shards` against a resident-version
    // mirror, exactly what `Exec::pinned_is_current` provides): each
    // simulated step mutates either ONE shard's head slice (locality p)
    // or a whole row (all shards), then "uploads" — materializes — every
    // stale shard plane. S=1 must upload the whole slab on any mutation;
    // S=4 uploads only what moved.
    println!("\n=== sharded slab: input prep + upload amplification ===");
    use fastkv::coordinator::decode::{shard_pin_keys, stale_shards};
    use std::collections::HashMap;
    let sm = ModelMeta {
        n_kv_heads: 4,
        head_dim: 12, // same row width as the meta above (48 f32)
        ..meta()
    };
    let cap_s = 576usize;
    let retained_s = 256usize;
    // Input-prep: scratch-buffered table/lens fills vs fresh allocations.
    {
        let mut paged =
            PagedArena::new(&sm, b, cap_s, PagingConfig::default());
        for i in 0..b as u64 {
            let rc = cache(&sm, 70 + i, retained_s);
            KvStore::admit(&mut paged, &rc).unwrap();
        }
        let view = paged.view();
        let mb = view.max_blocks;
        bench("input prep, fresh Vec per step (old)", 2, 200, || {
            let tables = view.tables_tensor(mb);
            let lens = view.lens_tensor();
            std::hint::black_box((&tables.data[0], &lens.data[0]));
        });
        let mut tables = fastkv::tensor::HostTensorI32::empty();
        let mut lens = fastkv::tensor::HostTensorI32::empty();
        bench("input prep, reused scratch buffers", 2, 200, || {
            view.tables_tensor_into(mb, &mut tables);
            view.lens_tensor_into(&mut lens);
            std::hint::black_box((&tables.data[0], &lens.data[0]));
        });
    }
    // Upload amplification sweep: fraction of steps whose mutation is
    // confined to one shard (0.0 = every step appends whole rows).
    let steps = if bench_util::quick() { 40 } else { 200 };
    let mut sweep_rows = Vec::new();
    for &locality in &[0.0f64, 0.5, 1.0] {
        let mut per_s: Vec<(usize, usize, usize)> = Vec::new(); // (S, uploads, bytes)
        for &s in &[1usize, 4] {
            let cfg = PagingConfig { shards: s, ..PagingConfig::default() };
            let mut pa = PagedArena::new(&sm, b, cap_s, cfg);
            let mut slots = Vec::new();
            for i in 0..b as u64 {
                let rc = cache(&sm, 70 + i, retained_s);
                slots.push(KvStore::admit(&mut pa, &rc).unwrap());
            }
            let srw = pa.shard_spec().shard_row_elems();
            let mut mirror: HashMap<String, u64> = HashMap::new();
            let mut rng = Rng::new(1234);
            let mut uploads = 0usize;
            let mut bytes = 0usize;
            let step =
                HostTensor::zeros(vec![sm.n_layers, b, sm.n_kv_heads, sm.head_dim]);
            // prime: first step uploads everything (both shapes pay it)
            for t in 0..steps {
                if t > 0 {
                    if rng.f64() < locality {
                        let shard = rng.below(pa.shard_spec().shards);
                        assert!(pa.mutate_shard_row(
                            slots[0],
                            0,
                            0,
                            shard,
                            &vec![t as f32; srw],
                            &vec![-(t as f32); srw],
                        ));
                    } else {
                        for &sl in &slots {
                            let _ = KvStore::append(&mut pa, sl, &step, &step);
                        }
                    }
                }
                let view = pa.view();
                let keys = shard_pin_keys(&view);
                let stale = stale_shards(&view, &keys, &|k, v| {
                    mirror.get(k).copied() == Some(v)
                });
                for &sh in &stale {
                    // the real upload cost: materialize the stale plane(s)
                    let (tk, tv) = if view.shards > 1 {
                        view.view_shard(sh).slab_tensors(view.num_blocks)
                    } else {
                        view.slab_tensors(view.num_blocks)
                    };
                    bytes += (tk.data.len() + tv.data.len()) * 4;
                    std::hint::black_box((&tk.data[0], &tv.data[0]));
                    let ver = if view.shards > 1 {
                        view.shard_versions[sh]
                    } else {
                        view.version
                    };
                    mirror.insert(keys[sh].0.clone(), ver);
                    mirror.insert(keys[sh].1.clone(), ver);
                    uploads += 1;
                }
            }
            // acceptance: under full locality a sharded store re-uploads
            // exactly one shard per step (plus the S-shard prime)
            if s > 1 && (locality - 1.0).abs() < f64::EPSILON {
                assert_eq!(
                    uploads,
                    s + (steps - 1),
                    "single-shard mutations must re-upload one shard each"
                );
            }
            println!(
                "{:44} {uploads:6} shard uploads, {:8.1} MiB moved",
                format!("locality {locality:.1}, S={s} ({steps} steps)"),
                bytes as f64 / (1 << 20) as f64
            );
            per_s.push((s, uploads, bytes));
        }
        sweep_rows.push((locality, per_s));
    }
    let flat_bytes = |rows: &[(f64, Vec<(usize, usize, usize)>)], loc: f64, s: usize| {
        rows.iter()
            .find(|(l, _)| (*l - loc).abs() < f64::EPSILON)
            .and_then(|(_, v)| v.iter().find(|(sh, _, _)| *sh == s))
            .map(|&(_, u, by)| (u, by))
            .unwrap()
    };
    let (u1, b1) = flat_bytes(&sweep_rows, 1.0, 1);
    let (u4, b4) = flat_bytes(&sweep_rows, 1.0, 4);
    let json = format!(
        "{{\n  \"steps\": {steps},\n  \"batch\": {b},\n  \"kv_heads\": {},\n  \
         \"sweep\": [\n{}\n  ],\n  \
         \"local_mutation_bytes_s1\": {b1},\n  \
         \"local_mutation_bytes_s4\": {b4},\n  \
         \"upload_bytes_reduction_at_full_locality\": {:.3},\n  \
         \"uploads_s1\": {u1},\n  \"uploads_s4\": {u4}\n}}\n",
        sm.n_kv_heads,
        sweep_rows
            .iter()
            .map(|(loc, v)| {
                let cells = v
                    .iter()
                    .map(|(s, u, by)| format!(
                        "{{\"shards\": {s}, \"uploads\": {u}, \"bytes\": {by}}}"
                    ))
                    .collect::<Vec<_>>()
                    .join(", ");
                format!("    {{\"locality\": {loc}, \"runs\": [{cells}]}}")
            })
            .collect::<Vec<_>>()
            .join(",\n"),
        b1 as f64 / b4 as f64,
    );
    std::fs::write("BENCH_paging_shard.json", &json)
        .expect("write BENCH_paging_shard.json");
    println!("\nwrote BENCH_paging_shard.json:\n{json}");

    // --------------------------------------------------------------------
    // 2-tenant contention: a heavy tenant churning large admissions
    // against a light tenant's small ones over a tight pool. Quotas OFF:
    // the light tenant admits only when the heavy churn happens to leave
    // room. Quotas ON (reserved floor for the light tenant): the light
    // tenant admits every round; the quota accounting itself must not
    // measurably slow the admit hot path.
    println!("\n=== 2-tenant contention: quotas off vs reserved floor ===");
    let heavy = TenantId(0);
    let light = TenantId(1);
    let heavy_len = 512usize;
    let light_len = 64usize;
    let rounds = if bench_util::quick() { 20 } else { 200 };
    let bt = PagingConfig::default().block_tokens;
    let heavy_rc: Vec<RequestCache> =
        (0..3u64).map(|i| cache(&m, 80 + i, heavy_len)).collect();
    let light_rc = cache(&m, 90, light_len);
    let blocks_of = |rc: &RequestCache| -> usize {
        rc.lens.iter().map(|&n| (n + bt - 1) / bt).sum()
    };
    let heavy_blocks = blocks_of(&heavy_rc[0]);
    // pool: exactly three heavy lanes saturate it — with quotas off the
    // light tenant finds nothing left; the reserved floor carves out one
    // light admission (+ a growth block per layer of margin)
    let pool = 3 * heavy_blocks;
    let light_floor = blocks_of(&light_rc) + m.n_layers;
    let mut results = Vec::new(); // (label, light_admits, denials, mean_ms)
    for quota_on in [false, true] {
        let mut cfg = PagingConfig {
            num_blocks: Some(pool),
            prefix_cache: false,
            swap_bytes: 0,
            ..PagingConfig::default()
        };
        if quota_on {
            cfg.tenant_quotas =
                vec![(light, TenantQuota::reserved(light_floor))];
        }
        let mut pa = PagedArena::new(&m, b, heavy_len + 64, cfg);
        let mut light_admits = 0usize;
        let mut heavy_admits = 0usize;
        let label = if quota_on {
            "contended round (light floor reserved)"
        } else {
            "contended round (quotas off)"
        };
        let t0 = std::time::Instant::now();
        for _round in 0..rounds {
            // heavy churn: admit as many large caches as fit, keep them
            // one round, release the oldest
            let mut held: Vec<usize> = Vec::new();
            for rc in &heavy_rc {
                if let Some(s) = pa.admit_for(rc, heavy) {
                    held.push(s);
                    heavy_admits += 1;
                }
            }
            // the light tenant tries one small admission per round
            if let Some(s) = pa.admit_for(&light_rc, light) {
                light_admits += 1;
                pa.release(s);
            }
            for s in held {
                pa.release(s);
            }
        }
        let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / rounds as f64;
        let ps = pa.pool_stats();
        println!(
            "{label:44} {mean_ms:10.3} ms/round  light {light_admits}/{rounds} \
             admits, heavy {heavy_admits}, quota denials {}",
            ps.quota_denials
        );
        results.push((quota_on, light_admits, ps.quota_denials, mean_ms));
    }
    let (_, light_off, _, ms_off) = results[0];
    let (_, light_on, denials_on, ms_on) = results[1];
    assert_eq!(
        light_on, rounds,
        "reserved floor must admit the light tenant every round"
    );
    let json = format!(
        "{{\n  \"pool_blocks\": {pool},\n  \"heavy_len\": {heavy_len},\n  \
         \"light_len\": {light_len},\n  \"light_floor_blocks\": {light_floor},\n  \
         \"rounds\": {rounds},\n  \"light_admits_quota_off\": {light_off},\n  \
         \"light_admits_quota_on\": {light_on},\n  \
         \"quota_denials_on\": {denials_on},\n  \
         \"round_ms_quota_off\": {ms_off:.4},\n  \
         \"round_ms_quota_on\": {ms_on:.4},\n  \
         \"quota_overhead\": {:.3}\n}}\n",
        ms_on / ms_off.max(1e-9),
    );
    std::fs::write("BENCH_paging_tenants.json", &json)
        .expect("write BENCH_paging_tenants.json");
    println!("\nwrote BENCH_paging_tenants.json:\n{json}");

    // --------------------------------------------------------------------
    // In-slab quantization: lane capacity at an EQUAL resident-byte
    // budget per precision tier, plus the decode input-prep cost of each
    // tier. The int8 tier must fit ~4x the f32 lane count in the same
    // pool bytes (each row pays a 4-byte scale per plane); its decode
    // prep ships the quantized planes + scales as-is (dequantization
    // happens in-HLO on the `decode_paged_q8` artifact), so only the
    // host-dequant *fallback* — a pool without that artifact — pays a
    // conversion per stale upload, measured separately.
    println!("\n=== in-slab quantization: lane capacity + prep per tier ===");
    use fastkv::KvCodec;
    let re = m.n_kv_heads * m.head_dim;
    let bt = PagingConfig::default().block_tokens;
    let budget_bytes = 6usize << 20;
    let admit_len = 256usize;
    let lane_slots = 64usize;
    // (codec, blocks, lanes, slab_bytes, prep_ms, host_dequant_ms)
    let mut tiers: Vec<(KvCodec, usize, usize, usize, f64, f64)> = Vec::new();
    for codec in KvCodec::ALL {
        let blocks = budget_bytes / (2 * bt * codec.bytes_per_row(re));
        let cfg = PagingConfig {
            num_blocks: Some(blocks),
            prefix_cache: false,
            swap_bytes: 0,
            precision: codec,
            ..PagingConfig::default()
        };
        let mut pa = PagedArena::new(&m, lane_slots, admit_len + 64, cfg);
        let mut lanes = 0usize;
        while lanes < lane_slots {
            let rc = cache(&m, 300 + lanes as u64, admit_len);
            match KvStore::admit(&mut pa, &rc) {
                Some(_) => lanes += 1,
                None => break,
            }
        }
        let slab_bytes = pa.pool_stats().slab_bytes;
        assert!(slab_bytes <= budget_bytes, "tier pool within the budget");
        assert!(lanes > 0 && lanes < lane_slots, "refusal, not lane cap");
        let view = pa.view();
        let nb = view.num_blocks;
        let prep_ms = if codec == KvCodec::Int8PerRow {
            let mut kq = HostTensor::empty();
            let mut ksc = HostTensor::empty();
            let mut vq = HostTensor::empty();
            let mut vsc = HostTensor::empty();
            bench(
                &format!("decode prep {} ({lanes} lanes)", codec.name()),
                2,
                20,
                || {
                    assert!(view.q8_slab_tensors_into(
                        nb, &mut kq, &mut ksc, &mut vq, &mut vsc
                    ));
                    std::hint::black_box((&kq.data[0], &ksc.data[0]));
                },
            )
            .mean_ms
        } else {
            bench(
                &format!("decode prep {} ({lanes} lanes)", codec.name()),
                2,
                20,
                || {
                    let (sk, sv) = view.slab_tensors(nb);
                    std::hint::black_box((&sk.data[0], &sv.data[0]));
                },
            )
            .mean_ms
        };
        let host_dequant_ms = if codec == KvCodec::Int8PerRow {
            bench(&format!("  host-dequant fallback ({lanes} lanes)"), 2, 20, || {
                let (sk, sv) = view.slab_tensors(nb);
                std::hint::black_box((&sk.data[0], &sv.data[0]));
            })
            .mean_ms
        } else {
            0.0
        };
        println!(
            "{:>46} {} blocks, {lanes} lanes before refusal, slab {:.2} MiB",
            "",
            blocks,
            slab_bytes as f64 / (1 << 20) as f64
        );
        tiers.push((codec, blocks, lanes, slab_bytes, prep_ms, host_dequant_ms));
    }
    let lanes_of = |c: KvCodec| {
        tiers.iter().find(|t| t.0 == c).map(|t| t.2).unwrap()
    };
    let f32_lanes = lanes_of(KvCodec::F32);
    let f16_lanes = lanes_of(KvCodec::F16);
    let q8_lanes = lanes_of(KvCodec::Int8PerRow);
    assert!(
        q8_lanes as f64 >= 1.9 * f32_lanes as f64,
        "int8 must fit >=1.9x the f32 lanes at equal pool bytes \
         ({q8_lanes} vs {f32_lanes})"
    );
    let json = format!(
        "{{\n  \"budget_bytes\": {budget_bytes},\n  \"block_tokens\": {bt},\n  \
         \"row_elems\": {re},\n  \"admit_tokens\": {admit_len},\n  \
         \"tiers\": [\n{}\n  ],\n  \
         \"lanes_f32\": {f32_lanes},\n  \"lanes_f16\": {f16_lanes},\n  \
         \"lanes_int8\": {q8_lanes},\n  \
         \"lanes_int8_vs_f32\": {:.3},\n  \"lanes_f16_vs_f32\": {:.3}\n}}\n",
        tiers
            .iter()
            .map(|(c, blocks, lanes, sb, prep, deq)| format!(
                "    {{\"codec\": \"{}\", \"blocks\": {blocks}, \
                 \"lanes\": {lanes}, \"slab_bytes\": {sb}, \
                 \"prep_ms\": {prep:.4}, \"host_dequant_ms\": {deq:.4}}}",
                c.name()
            ))
            .collect::<Vec<_>>()
            .join(",\n"),
        q8_lanes as f64 / f32_lanes as f64,
        f16_lanes as f64 / f32_lanes as f64,
    );
    std::fs::write("BENCH_paging_quant.json", &json)
        .expect("write BENCH_paging_quant.json");
    println!("\nwrote BENCH_paging_quant.json:\n{json}");

    // --------------------------------------------------------------------
    // Decode-phase budgets: long-generation contention. Every lane keeps
    // generating on top of a 256-token prompt. Unbudgeted, the decode
    // region grows a block per `block_tokens` appends per layer, forever;
    // budgeted (two-stage eviction + sliding window), the coarse stage
    // releases cold generated blocks so residency stays O(budget) and the
    // fine stage hands each step a pruned block table whose prep cost
    // follows the budget rather than the tokens generated. A second pass
    // replays both on a pool sized for the *budgeted* peak: the budgeted
    // lanes run the full generation, the unbudgeted ones stall on
    // PoolExhausted — the contention headline.
    println!("\n=== decode budgets: long-generation contention ===");
    let gen_steps = if bench_util::quick() { 128 } else { 512 };
    let prompt_len = 256usize;
    let cap_d = prompt_len + gen_steps + 8;
    let dbudget = PolicyCfg {
        kv_rate: 1.0,
        tsp_rate: 1.0,
        sinks: 4,
        filter_layer: 0,
        use_pallas: false,
        prefill_budget: 0,
        decode_budget: 32,
        decode_window: m.window,
        prefill_chunk: 0,
        prefill_decode_ratio: 1,
    }
    .decode_budget_spec()
    .expect("decode budget configured");
    // (steps completed, peak held blocks, decode-region gauge,
    //  coarse releases, pruned blocks in the last view, prep ms/step)
    let run = |budget: Option<&DecodeBudget>,
               pool: Option<usize>|
     -> (usize, usize, usize, usize, usize, f64) {
        let cfg_d = PagingConfig {
            num_blocks: pool,
            prefix_cache: false,
            swap_bytes: 0,
            ..PagingConfig::default()
        };
        let mut pa = PagedArena::new(&m, b, cap_d, cfg_d);
        let slots: Vec<usize> = (0..b as u64)
            .map(|i| {
                KvStore::admit(&mut pa, &cache(&m, 200 + i, prompt_len))
                    .unwrap()
            })
            .collect();
        let step = HostTensor::zeros(vec![
            m.n_layers,
            b,
            m.n_kv_heads,
            m.head_dim,
        ]);
        let mut tables = fastkv::tensor::HostTensorI32::empty();
        let mut lens_t = fastkv::tensor::HostTensorI32::empty();
        let mut peak_held = 0usize;
        let mut released = 0usize;
        let mut pruned_last = 0usize;
        let mut steps_done = 0usize;
        let mut prep_s = 0.0f64;
        'steps: for _ in 0..gen_steps {
            for &s in &slots {
                if KvStore::append(&mut pa, s, &step, &step)
                    != AppendResult::Ok
                {
                    break 'steps;
                }
            }
            // peak residency is right here: after the appends, before the
            // coarse stage runs (this sizes the tight pool below)
            let held: usize =
                slots.iter().map(|&s| KvStore::held_blocks(&pa, s)).sum();
            peak_held = peak_held.max(held);
            if let Some(bgt) = budget {
                for &s in &slots {
                    released +=
                        KvStore::enforce_decode_budget(&mut pa, s, bgt);
                }
            }
            let t0 = Instant::now();
            let view = pa.view_budgeted(budget);
            let mb = view.max_blocks;
            view.tables_tensor_into(mb, &mut tables);
            view.lens_tensor_into(&mut lens_t);
            prep_s += t0.elapsed().as_secs_f64();
            pruned_last = view.pruned_blocks;
            std::hint::black_box((&tables.data[0], &lens_t.data[0]));
            steps_done += 1;
        }
        let region = pa.pool_stats().decode_region_blocks;
        (
            steps_done,
            peak_held,
            region,
            released,
            pruned_last,
            prep_s * 1e3 / steps_done.max(1) as f64,
        )
    };
    let (steps_u, peak_u, region_u, rel_u, pruned_u, prep_u) =
        run(None, None);
    let (steps_b, peak_b, region_b, rel_b, pruned_b, prep_b) =
        run(Some(&dbudget), None);
    assert_eq!(steps_u, gen_steps, "roomy pool: unbudgeted run completes");
    assert_eq!(steps_b, gen_steps, "roomy pool: budgeted run completes");
    assert_eq!(rel_u, 0, "unbudgeted run must release nothing");
    assert_eq!(pruned_u, 0, "unbudgeted view must be unpruned");
    assert!(rel_b > 0, "tight budget must coarse-release cold blocks");
    assert!(pruned_b > 0, "tight budget must prune the decode view");
    assert!(peak_b < peak_u, "budget must bound the resident-block peak");
    println!(
        "{:44} peak {peak_u:5} blocks, region {region_u:5}, prep {prep_u:8.4} ms/step",
        format!("unbudgeted ({gen_steps} steps x {b} lanes)")
    );
    println!(
        "{:44} peak {peak_b:5} blocks, region {region_b:5}, prep {prep_b:8.4} ms/step",
        format!(
            "budgeted (fine {}, coarse {}, win {})",
            dbudget.fine_rows, dbudget.coarse_rows, dbudget.window
        )
    );
    // contention replay: pool sized for the budgeted peak (+ one growth
    // block per lane-layer of slack)
    let tight_pool = peak_b + m.n_layers * b;
    let (tight_steps_u, ..) = run(None, Some(tight_pool));
    let (tight_steps_b, ..) = run(Some(&dbudget), Some(tight_pool));
    assert_eq!(
        tight_steps_b, gen_steps,
        "budgeted lanes must finish the generation on the tight pool"
    );
    assert!(
        tight_steps_u < gen_steps,
        "unbudgeted lanes must stall on the tight pool"
    );
    println!(
        "{:44} budgeted {tight_steps_b}/{gen_steps} steps, unbudgeted \
         stalls at {tight_steps_u}",
        format!("tight pool ({tight_pool} blocks)")
    );
    // Scratch-vs-fresh prep with pruning enabled: the budgeted view must
    // keep the allocation-free step path (`*_tensor_into` reuse).
    let mut pa = PagedArena::new(&m, b, cap_d, PagingConfig::default());
    let slots: Vec<usize> = (0..b as u64)
        .map(|i| {
            KvStore::admit(&mut pa, &cache(&m, 200 + i, prompt_len)).unwrap()
        })
        .collect();
    let step =
        HostTensor::zeros(vec![m.n_layers, b, m.n_kv_heads, m.head_dim]);
    for _ in 0..4 * dbudget.fine_rows {
        for &s in &slots {
            assert_eq!(KvStore::append(&mut pa, s, &step, &step), AppendResult::Ok);
        }
    }
    for &s in &slots {
        KvStore::enforce_decode_budget(&mut pa, s, &dbudget);
    }
    let view = pa.view_budgeted(Some(&dbudget));
    assert!(view.pruned_blocks > 0, "pruning engaged for the prep bench");
    let mb = view.max_blocks;
    let r_fresh = bench("pruned prep, fresh Vec per step", 2, 200, || {
        let tables = view.tables_tensor(mb);
        let lens = view.lens_tensor();
        std::hint::black_box((&tables.data[0], &lens.data[0]));
    });
    let mut tables = fastkv::tensor::HostTensorI32::empty();
    let mut lens_t = fastkv::tensor::HostTensorI32::empty();
    let r_scratch = bench("pruned prep, reused scratch buffers", 2, 200, || {
        view.tables_tensor_into(mb, &mut tables);
        view.lens_tensor_into(&mut lens_t);
        std::hint::black_box((&tables.data[0], &lens_t.data[0]));
    });
    let json = format!(
        "{{\n  \"gen_steps\": {gen_steps},\n  \"lanes\": {b},\n  \
         \"prompt_len\": {prompt_len},\n  \
         \"budget\": {{\"fine_rows\": {}, \"coarse_rows\": {}, \
         \"window\": {}, \"sinks\": {}}},\n  \
         \"peak_blocks_unbudgeted\": {peak_u},\n  \
         \"peak_blocks_budgeted\": {peak_b},\n  \
         \"retained_ratio\": {:.3},\n  \
         \"decode_region_unbudgeted\": {region_u},\n  \
         \"decode_region_budgeted\": {region_b},\n  \
         \"coarse_blocks_released\": {rel_b},\n  \
         \"pruned_blocks_last_step\": {pruned_b},\n  \
         \"prep_ms_unbudgeted\": {prep_u:.4},\n  \
         \"prep_ms_budgeted\": {prep_b:.4},\n  \
         \"tight_pool_blocks\": {tight_pool},\n  \
         \"tight_steps_unbudgeted\": {tight_steps_u},\n  \
         \"tight_steps_budgeted\": {tight_steps_b},\n  \
         \"pruned_prep_fresh_ms\": {:.4},\n  \
         \"pruned_prep_scratch_ms\": {:.4}\n}}\n",
        dbudget.fine_rows,
        dbudget.coarse_rows,
        dbudget.window,
        dbudget.sinks,
        peak_b as f64 / peak_u as f64,
        r_fresh.mean_ms,
        r_scratch.mean_ms,
    );
    std::fs::write("BENCH_paging_decode.json", &json)
        .expect("write BENCH_paging_decode.json");
    println!("\nwrote BENCH_paging_decode.json:\n{json}");

    // --------------------------------------------------------------------
    // Chunked prefill vs monolithic stall: 4 lanes decode while one long
    // admission prefills. Monolithic, the blocking policy prefill freezes
    // every decode lane for the whole prompt; chunked, one chunk runs per
    // loop slot with a decode round interleaved after each, so the worst
    // inter-token gap any lane sees is ~one chunk. The sim policy charges
    // a fixed per-token sleep, standing in for device prefill compute at
    // sim scale (the shape mirrors a 64k admission over 4 decode lanes,
    // scaled to the harness's 2-layer model).
    println!("\n=== chunked prefill: decode-lane interleaving ===");
    let long_len = 48usize;
    let chunk_tokens = 4usize;
    let cost_ns: u64 =
        if bench_util::quick() { 100_000 } else { 400_000 };
    let (mono_gap_ms, _) = serve_gap_run(0, cost_ns, long_len);
    let (chunked_gap_ms, chunks) =
        serve_gap_run(chunk_tokens, cost_ns, long_len);
    println!(
        "{:44} {mono_gap_ms:10.3} ms max inter-token gap",
        format!("monolithic admission ({long_len} tok prefill)")
    );
    println!(
        "{:44} {chunked_gap_ms:10.3} ms max inter-token gap ({chunks} chunks)",
        format!("chunked admission ({chunk_tokens}-tok chunks)")
    );
    assert!(
        chunked_gap_ms < mono_gap_ms,
        "chunked interleaving must bound the decode stall \
         ({chunked_gap_ms:.3} ms vs {mono_gap_ms:.3} ms)"
    );
    let json = format!(
        "{{\n  \"long_prompt_tokens\": {long_len},\n  \
         \"decode_lanes\": 4,\n  \"chunk_tokens\": {chunk_tokens},\n  \
         \"chunks\": {chunks},\n  \"cost_ns_per_token\": {cost_ns},\n  \
         \"max_gap_ms_monolithic\": {mono_gap_ms:.4},\n  \
         \"max_gap_ms_chunked\": {chunked_gap_ms:.4},\n  \
         \"gap_reduction\": {:.3}\n}}\n",
        mono_gap_ms / chunked_gap_ms.max(1e-9),
    );
    std::fs::write("BENCH_serve_chunked.json", &json)
        .expect("write BENCH_serve_chunked.json");
    println!("\nwrote BENCH_serve_chunked.json:\n{json}");
}

/// One serve-shaped interleaving run for `BENCH_serve_chunked.json`:
/// 4 lanes decode while one `long_len`-token admission prefills —
/// blocking when `chunk == 0`, chunked otherwise. Every decode round is
/// timestamped; the max gap between consecutive rounds is the stall the
/// admission imposed on the active lanes. Returns (max gap ms, chunks).
fn serve_gap_run(
    chunk: usize,
    cost_ns: u64,
    long_len: usize,
) -> (f64, usize) {
    use fastkv::coordinator::policies::Policy;
    use fastkv::coordinator::server::{admit, Request};
    use std::collections::HashMap;
    use std::time::Instant;

    let m = sim::sim_meta();
    let man = sim::sim_manifest(64);
    let mut cfg = sim::sim_server_cfg(64, 1_000);
    cfg.policy_cfg.prefill_chunk = chunk;
    cfg.policy_cfg.prefill_decode_ratio = 1;
    let policy = sim::SimPolicy::with_cost(cost_ns);
    let metrics = fastkv::metrics::Metrics::default();
    let pcfg = PagingConfig {
        block_tokens: 2,
        prefix_cache: false,
        swap_bytes: 0,
        ..PagingConfig::default()
    };
    let lanes = 4usize;
    let mut pa = PagedArena::new(&m, lanes + 1, 128, pcfg);
    let mut prompts: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut rxs = Vec::new(); // kept alive; the bench never replies
    let mut active = Vec::new();
    for i in 0..lanes as u64 {
        let p: Vec<i32> = (0..6).map(|j| 10 + i as i32 + j).collect();
        let (req, rx) = Request::synthetic(i, p.clone(), 1_000);
        rxs.push(rx);
        prompts.insert(i, p);
        match admit(&sim::NoExec, &man, &policy, &cfg, req, &mut pa, &metrics)
        {
            Ok(a) => active.push(a),
            Err(_) => unreachable!("roomy pool refused a decode lane"),
        }
    }
    let long: Vec<i32> =
        (0..long_len as i32).map(|t| 4 + (t % 200)).collect();
    let (mut req, rx) = Request::synthetic(99, long.clone(), 1_000);
    rxs.push(rx);
    prompts.insert(99, long.clone());
    let mut ticks: Vec<Instant> = Vec::new();
    let mut chunks_run = 0usize;
    sim::sim_decode_round(&mut pa, &mut active, &prompts, &cfg, &metrics);
    ticks.push(Instant::now());
    if chunk == 0 {
        // Blocking monolithic admission: every decode lane stalls for
        // the whole prefill.
        match admit(&sim::NoExec, &man, &policy, &cfg, req, &mut pa, &metrics)
        {
            Ok(a) => active.push(a),
            Err(_) => unreachable!("roomy pool refused the long admission"),
        }
    } else {
        let mut ch = policy
            .begin_chunked(&man, &long, &cfg.policy_cfg)
            .expect("chunk knob on")
            .expect("sim begin_chunked never refuses");
        let mut secs = 0.0f64;
        while ch.chunks_done() < ch.total_chunks() {
            let t0 = Instant::now();
            ch.step(&sim::NoExec, &man).unwrap();
            secs += t0.elapsed().as_secs_f64();
            chunks_run += 1;
            sim::sim_decode_round(
                &mut pa,
                &mut active,
                &prompts,
                &cfg,
                &metrics,
            );
            ticks.push(Instant::now());
        }
        let outcome = ch.finish(&sim::NoExec, &man).unwrap();
        req.carry_prefill(outcome, secs);
        match admit(&sim::NoExec, &man, &policy, &cfg, req, &mut pa, &metrics)
        {
            Ok(a) => active.push(a),
            Err(_) => unreachable!("roomy pool refused the carried prefill"),
        }
    }
    for _ in 0..2 {
        sim::sim_decode_round(&mut pa, &mut active, &prompts, &cfg, &metrics);
        ticks.push(Instant::now());
    }
    let max_gap_ms = ticks
        .windows(2)
        .map(|w| w[1].duration_since(w[0]).as_secs_f64() * 1e3)
        .fold(0.0, f64::max);
    (max_gap_ms, chunks_run)
}
