//! Table 8 bench: token-importance estimation overhead — coordinator-side
//! selection cost (head-mean + max-pool + group-wise top-k over all
//! layers) vs the artifact prefill itself.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::bench;
use fastkv::coordinator::policies::Exec;
use fastkv::coordinator::selection;
use fastkv::runtime::outputs::PrefillFullOut;
use fastkv::runtime::{In, Runtime};
use fastkv::tensor::HostTensorI32;
use fastkv::tokenizer::Tokenizer;
use fastkv::util::rng::Rng;
use fastkv::workload;

fn main() {
    let rt = match Runtime::new(&fastkv::Manifest::default_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench (no artifacts): {e}");
            return;
        }
    };
    let man = rt.manifest.clone();
    let tok = Tokenizer;
    println!("\n=== estimation_overhead (Table 8) ===");
    for &len in &man.buckets.prefill_ns.clone() {
        if len < 256 {
            continue;
        }
        let mut rng = Rng::new(1);
        let s = workload::kv_recall(&mut rng, len, None, 1);
        let mut ids = tok.encode(&s.prompt);
        ids.resize(len, 0);
        let run_prefill = || {
            PrefillFullOut::from_vec(
                Exec::run(
                    &rt,
                    &format!("prefill_full_{len}"),
                    vec![
                        HostTensorI32::new(vec![len], ids.clone()).into(),
                        In::scalar_i32(len as i32),
                    ],
                )
                .unwrap(),
            )
        };
        let out = run_prefill();
        let pre =
            bench(&format!("prefill_full_{len}"), 1, 3, || {
                let _ = run_prefill();
            });
        let budget = (0.1 * len as f64).ceil() as usize;
        let est = bench(&format!("estimation (all layers) @{len}"), 1, 10, || {
            for l in 0..man.model.n_layers {
                let _ = selection::select_kv_groupwise(
                    out.win.row(l),
                    man.model.n_heads,
                    out.win.shape[2],
                    len,
                    man.model.n_kv_heads,
                    budget,
                    man.model.window,
                    man.model.pool_kernel,
                );
            }
        });
        println!(
            "{:>46} overhead = {:.2}% of prefill",
            "",
            100.0 * est.mean_ms / (pre.mean_ms + est.mean_ms)
        );
    }
}
