//! L3 hot-path micro-benchmarks: selection primitives and KV-cache arena
//! operations, independent of PJRT (used by the §Perf iteration loop).

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::bench;
use fastkv::coordinator::kvcache::{BatchArena, RequestCache};
use fastkv::coordinator::selection;
use fastkv::manifest::ModelMeta;
use fastkv::tensor::HostTensor;
use fastkv::util::rng::Rng;

fn meta() -> ModelMeta {
    ModelMeta {
        vocab_size: 256,
        d_model: 96,
        n_layers: 8,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 24,
        tsp_layer: 4,
        window: 8,
        pool_kernel: 7,
        max_train_len: 512,
    }
}

fn main() {
    let m = meta();
    let mut rng = Rng::new(0);
    println!("\n=== selection_hotpath (L3 §Perf) ===");
    for n in [512usize, 2048, 8192] {
        let win: Vec<f32> =
            (0..m.n_heads * n).map(|_| rng.f64() as f32).collect();
        bench(&format!("select_kv_groupwise n={n}"), 3, 50, || {
            let _ = selection::select_kv_groupwise(
                &win,
                m.n_heads,
                n,
                n,
                m.n_kv_heads,
                n / 10,
                m.window,
                m.pool_kernel,
            );
        });
        bench(&format!("maxpool1d n={n}"), 3, 50, || {
            let s = selection::head_mean(&win, m.n_heads, n);
            let _ = selection::maxpool1d(&s, m.pool_kernel);
        });
    }

    // KV gather + arena load/append path
    let n = 2048;
    let k_src = HostTensor::zeros(vec![m.n_layers, n, m.n_kv_heads, m.head_dim]);
    let v_src = k_src.clone();
    let sel: Vec<usize> = (0..n / 10).map(|i| i * 10).collect();
    bench("RequestCache fill (8 layers, 2048->205)", 3, 50, || {
        let mut rc = RequestCache::new(&m);
        for l in 0..m.n_layers {
            rc.fill_layer(l, &k_src, &v_src, l, &sel);
        }
    });

    let mut rc = RequestCache::new(&m);
    for l in 0..m.n_layers {
        rc.fill_layer(l, &k_src, &v_src, l, &sel);
    }
    let mut arena = BatchArena::new(&m, 4, 320);
    let slot = arena.alloc_slot().unwrap();
    bench("BatchArena load (cap 320)", 3, 100, || {
        arena.load(slot, &rc);
    });
    let k_new = HostTensor::zeros(vec![m.n_layers, 4, m.n_kv_heads, m.head_dim]);
    bench("BatchArena append", 3, 100, || {
        if !arena.append(slot, &k_new, &k_new) {
            arena.free_slot(slot);
            let _ = arena.alloc_slot();
            arena.load(slot, &rc);
        }
    });
}
