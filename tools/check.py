#!/usr/bin/env python3
"""Cross-layer consistency checks for the fastkv repo.

The repo spans four planes that agree by convention alone: Rust metric
consts (`metrics::names`) vs docs/metrics.md vs publish sites; the
Python artifact emitter (aot.py) vs the Rust bucket resolvers
(manifest.rs / decode.rs); CLI flags vs README/docs; lifecycle event
variants vs their consumers; bench artifact names vs the CI steps that
cat / assert on / upload them. This tool pins every one of those couplings
mechanically. Stdlib-only so it runs in toolchain-free containers and as
a no-Rust CI lane.

Usage:
    python3 tools/check.py                 # all checks, repo root inferred
    python3 tools/check.py --only metrics,cli
    python3 tools/check.py --root /some/tree
    python3 tools/check.py --list

Exit status 0 iff no findings. Each finding prints as
`<check>: <message>`. See docs/static-analysis.md for what each check
parses and how to add one.
"""

import argparse
import os
import re
import sys

# --------------------------------------------------------------- helpers

PLACEHOLDER = re.compile(r"\{[^{}]*\}")


def read(root, rel):
    """Return the text of root/rel, or None if it does not exist."""
    path = os.path.join(root, rel)
    if not os.path.exists(path):
        return None
    with open(path, encoding="utf-8") as f:
        return f.read()


def strip_tests(src):
    """Drop the trailing `#[cfg(test)] mod tests` block.

    Repo convention keeps unit tests as the final item of a file, so
    truncating at the first `#[cfg(test)]` is exact here and avoids
    brace-matching through string literals.
    """
    idx = src.find("#[cfg(test)]")
    return src if idx < 0 else src[:idx]


def brace_block(src, start):
    """Return src[open..close] for the first balanced {...} at/after start."""
    open_idx = src.index("{", start)
    depth = 0
    for i in range(open_idx, len(src)):
        c = src[i]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                return src[open_idx : i + 1]
    raise ValueError("unbalanced braces")


def normalize(template):
    """`tenant_{t}_blocks_held` -> `tenant_{}_blocks_held` for matching."""
    return PLACEHOLDER.sub("{}", template)


def placeholders(template):
    return PLACEHOLDER.findall(template)


def rust_sources(root):
    """(relpath, text) for first-party Rust sources: src, tests, benches,
    examples — vendor crates excluded."""
    out = []
    for sub in ("rust/src", "rust/tests", "rust/benches", "examples"):
        base = os.path.join(root, sub)
        for dirpath, _dirnames, filenames in os.walk(base):
            for fn in sorted(filenames):
                if fn.endswith(".rs"):
                    rel = os.path.relpath(os.path.join(dirpath, fn), root)
                    out.append((rel, read(root, rel)))
    return out


def docs_corpus(root):
    """Markdown files that count as user-facing documentation."""
    rels = []
    for fn in sorted(os.listdir(root)):
        if fn.endswith(".md"):
            rels.append(fn)
    docs_dir = os.path.join(root, "docs")
    if os.path.isdir(docs_dir):
        for fn in sorted(os.listdir(docs_dir)):
            if fn.endswith(".md"):
                rels.append(os.path.join("docs", fn))
    for sub in ("rust", "python"):
        base = os.path.join(root, sub)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d != "vendor"]
            if "README.md" in filenames:
                rels.append(
                    os.path.relpath(os.path.join(dirpath, "README.md"), root)
                )
    return rels


# ------------------------------------------------------------ 1. metrics

METRICS_RS = "rust/src/metrics.rs"
METRICS_MD = "docs/metrics.md"

CONST_RE = re.compile(
    r'pub const ([A-Z][A-Z0-9_]*): &str =\s*"([^"]+)";', re.S
)
TEMPLATE_FN_RE = re.compile(
    r'pub fn ([a-z][a-z0-9_]*)\s*\([^)]*\)\s*->\s*String\s*\{\s*'
    r'format!\(\s*"([^"]+)"',
    re.S,
)


def metric_code_names(src):
    """All metric names defined in `metrics::names`, as
    {normalized: (ident, raw_template, is_fn)}."""
    names_mod = brace_block(src, src.index("pub mod names"))
    out = {}
    for ident, raw in CONST_RE.findall(names_mod):
        out[normalize(raw)] = (ident, raw, False)
    for ident, raw in TEMPLATE_FN_RE.findall(names_mod):
        out[normalize(raw)] = (ident, raw, True)
    return out


def metric_doc_rows(md):
    """First backticked token of every markdown table row, raw spelling."""
    rows = []
    for line in md.splitlines():
        if not line.startswith("|"):
            continue
        cell = line.split("|")[1].strip()
        m = re.match(r"`([^`]+)`", cell)
        if m and not set(m.group(1)) <= set("-: "):
            rows.append(m.group(1))
    return rows


def check_metrics(root, findings):
    src = read(root, METRICS_RS)
    md = read(root, METRICS_MD)
    if src is None or md is None:
        findings.append(f"missing {METRICS_RS if src is None else METRICS_MD}")
        return
    code = metric_code_names(strip_tests(src))
    doc_raw = metric_doc_rows(md)
    doc = {normalize(r): r for r in doc_raw}

    # every code name has a doc row, with placeholder spellings agreeing
    for key, (ident, raw, is_fn) in sorted(code.items()):
        if key not in doc:
            findings.append(
                f"metric `{raw}` ({ident}) has no row in {METRICS_MD}"
            )
            continue
        code_ph = placeholders(raw)
        doc_ph = placeholders(doc[key])
        for c, d in zip(code_ph, doc_ph):
            if c == d:
                continue
            # a doc-side enumeration `{f32,f16,int8}` may document a
            # positional `{}` in the code template; anything else is
            # spelling drift (`{t}` vs `{id}`).
            if c == "{}" and "," in d:
                continue
            findings.append(
                f"metric template `{raw}` ({ident}) documented as "
                f"`{doc[key]}` in {METRICS_MD}: placeholder `{c}` vs `{d}`"
            )

    # every doc row maps back to a const / template fn
    for key, raw in sorted(doc.items()):
        if key not in code:
            findings.append(
                f"{METRICS_MD} documents `{raw}` but metrics::names "
                "defines no such const or template fn"
            )

    # every code name is published at least once outside metrics.rs
    others = "\n".join(
        text for rel, text in rust_sources(root) if rel != METRICS_RS
    )
    for key, (ident, raw, is_fn) in sorted(code.items()):
        pat = f"names::{ident}" + ("(" if is_fn else "")
        if pat not in others:
            findings.append(
                f"metric `{raw}` ({ident}) has no publish site outside "
                f"{METRICS_RS} (searched for `{pat}`)"
            )


# ---------------------------------------------------------- 2. artifacts

MANIFEST_RS = "rust/src/manifest.rs"
AOT_PY = "python/compile/aot.py"
CONFIGS_PY = "python/compile/configs.py"

ARTIFACT_FN_RE = re.compile(
    r'pub fn ([a-z0-9_]*artifact_name[a-z0-9_]*)\s*\([^)]*\)\s*->\s*String'
    r'\s*\{\s*format!\(\s*"([^"]+)"',
    re.S,
)
FSTRING_RE = re.compile(r'f"([a-z][a-z0-9_]*(?:\{[^{}]*\}[a-z0-9_x]*)+)"')
MANIFEST_KEY_RE = re.compile(r'\.(?:req|get)\(\s*"([a-z_0-9]+)"\s*\)')


def check_artifacts(root, findings):
    man = read(root, MANIFEST_RS)
    aot = read(root, AOT_PY)
    cfgs = read(root, CONFIGS_PY) or ""
    if man is None or aot is None:
        findings.append(f"missing {MANIFEST_RS if man is None else AOT_PY}")
        return
    man = strip_tests(man)

    emitted = {normalize(t) for t in FSTRING_RE.findall(aot)}
    for fn_name, raw in ARTIFACT_FN_RE.findall(man):
        if normalize(raw) not in emitted:
            findings.append(
                f"{MANIFEST_RS}::{fn_name} resolves `{raw}` but {AOT_PY} "
                f"emits no artifact named `{normalize(raw)}` "
                f"(emitted families: {sorted(emitted)})"
            )

    # every manifest key the rust loader reads must be produced by the
    # python side: a literal key in aot.py, or a ModelConfig field in
    # configs.py (aot.py serializes the model block via cfg.to_dict()).
    for key in sorted(set(MANIFEST_KEY_RE.findall(man))):
        in_aot = f'"{key}"' in aot
        in_cfg = (
            f'"{key}"' in cfgs
            or re.search(rf"^\s+{key}\s*[:=]", cfgs, re.M) is not None
        )
        if not (in_aot or in_cfg):
            findings.append(
                f"{MANIFEST_RS} reads manifest key `{key}` but neither "
                f"{AOT_PY} (literal) nor {CONFIGS_PY} (ModelConfig field) "
                "produces it"
            )


# ---------------------------------------------------------------- 3. cli

MAIN_RS = "rust/src/main.rs"
CLI_RS = "rust/src/util/cli.rs"

FLAG_RE = re.compile(
    r'\.(?:get|has|usize|f64|str_or|usize_list|str_list)\(\s*"([a-z][a-z0-9-]*)"'
)
# (flag, phrase-that-must-appear-on-its-documentation-line)
PINNED_WORDING = [("swap-half", "swap-only tier")]


def check_cli(root, findings):
    flags = set()
    for rel in (MAIN_RS, CLI_RS):
        src = read(root, rel)
        if src is None:
            findings.append(f"missing {rel}")
            return
        flags |= set(FLAG_RE.findall(strip_tests(src)))

    corpus = {rel: read(root, rel) or "" for rel in docs_corpus(root)}
    blob = "\n".join(corpus.values())
    for flag in sorted(flags):
        if not re.search(rf"--{re.escape(flag)}(?![a-z0-9-])", blob):
            findings.append(
                f"flag `--{flag}` (parsed in {MAIN_RS}/{CLI_RS}) is not "
                "documented in README.md or docs/"
            )

    for flag, phrase in PINNED_WORDING:
        if flag not in flags:
            continue
        doc_lines = [
            line
            for text in corpus.values()
            for line in text.splitlines()
            if f"--{flag}" in line
        ]
        if not any(phrase in line for line in doc_lines):
            findings.append(
                f"deprecated flag `--{flag}` must be documented with the "
                f"pinned wording `{phrase}` on at least one doc line "
                f"({len(doc_lines)} doc lines mention it, none match)"
            )


# -------------------------------------------------------- 4. lifecycle

TRACE_RS = "rust/src/obs/trace.rs"
EXPORT_RS = "rust/src/obs/export.rs"

VARIANT_RE = re.compile(r"^\s{4}([A-Z][A-Za-z0-9]*)\s*(?:\{|,|$)", re.M)


def event_variants(src):
    enum = brace_block(src, src.index("pub enum EventKind"))
    return VARIANT_RE.findall(enum)


def check_lifecycle(root, findings):
    trace = read(root, TRACE_RS)
    export = read(root, EXPORT_RS)
    if trace is None or export is None:
        findings.append(f"missing {TRACE_RS if trace is None else EXPORT_RS}")
        return
    trace = strip_tests(trace)
    variants = event_variants(trace)
    if not variants:
        findings.append(f"no EventKind variants parsed from {TRACE_RS}")
        return

    start = trace.find("fn validate_lifecycle")
    if start < 0:
        findings.append(f"{TRACE_RS}: fn validate_lifecycle not found")
        return
    body = brace_block(trace, start)
    for v in variants:
        if not re.search(rf"\b(?:K|EventKind)::{v}\b", body):
            findings.append(
                f"EventKind::{v} is not handled in validate_lifecycle "
                f"({TRACE_RS})"
            )

    export = strip_tests(export)
    for v in variants:
        if not re.search(rf"\bEventKind::{v}\b", export):
            findings.append(
                f"EventKind::{v} is not handled by the Chrome-trace "
                f"exporter ({EXPORT_RS})"
            )


# ------------------------------------------------------------- 5. cargo

CARGO_TOML = "Cargo.toml"
PATH_INCLUDE_RE = re.compile(r'#\[path\s*=\s*"([^"]+)"\]')


def parse_cargo(text):
    """Minimal single-file TOML walk: section headers, `key = value`
    pairs, and inline `{ ... }` tables (this manifest uses nothing
    fancier). Returns (targets, deps): targets is a list of
    (section, {key: value}); deps is {section: {name: raw_value}}."""
    targets = []
    deps = {}
    section = None
    current = None
    for line in text.splitlines():
        line = line.split("#", 1)[0].strip() if not line.lstrip().startswith("#") else ""
        if not line:
            continue
        m = re.match(r"^\[+([a-z0-9._-]+)\]+$", line)
        if m:
            section = m.group(1)
            if line.startswith("[["):
                current = {}
                targets.append((section, current))
            else:
                current = None
            continue
        kv = re.match(r'^([A-Za-z0-9_-]+)\s*=\s*(.+)$', line)
        if not kv:
            continue
        key, value = kv.group(1), kv.group(2).strip()
        if current is not None:
            current[key] = value.strip('"')
        elif section and section.endswith("dependencies"):
            deps.setdefault(section, {})[key] = value
    return targets, deps


def check_cargo(root, findings):
    text = read(root, CARGO_TOML)
    if text is None:
        findings.append(f"missing {CARGO_TOML}")
        return
    targets, deps = parse_cargo(text)

    declared = {"test": set(), "bench": set()}
    for section, table in targets:
        if section not in declared:
            continue
        path = table.get("path")
        if not path:
            findings.append(
                f"[[{section}]] `{table.get('name', '?')}` has no path"
            )
            continue
        declared[section].add(path)
        if not os.path.exists(os.path.join(root, path)):
            findings.append(
                f"[[{section}]] `{table.get('name', '?')}` points at "
                f"missing file {path}"
            )

    # reverse direction: every file on disk is registered (helper files
    # pulled in via #[path = "..."] are modules, not targets)
    for kind, dirname in (("test", "rust/tests"), ("bench", "rust/benches")):
        base = os.path.join(root, dirname)
        if not os.path.isdir(base):
            continue
        included = set()
        for fn in os.listdir(base):
            if fn.endswith(".rs"):
                src = read(root, f"{dirname}/{fn}") or ""
                included |= set(PATH_INCLUDE_RE.findall(src))
        for fn in sorted(os.listdir(base)):
            rel = f"{dirname}/{fn}"
            if (
                fn.endswith(".rs")
                and rel not in declared[kind]
                and fn not in included
            ):
                findings.append(
                    f"{rel} exists but has no [[{kind}]] entry in "
                    f"{CARGO_TOML} (autodiscovery is off)"
                )

    for section, table in deps.items():
        for name, value in table.items():
            if "git" in value and "git =" in value:
                findings.append(
                    f"{CARGO_TOML} [{section}] `{name}` is a git "
                    f"dependency ({value}); only vendored path deps "
                    "are allowed"
                )
            elif "path =" not in value:
                findings.append(
                    f"{CARGO_TOML} [{section}] `{name}` = {value} is not "
                    "a vendored path dependency (no network registry in "
                    "this build environment)"
                )


# ------------------------------------------------------------- 6. links

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)")


def check_links(root, findings):
    rels = docs_corpus(root)
    for rel in rels:
        text = read(root, rel)
        base = os.path.dirname(os.path.join(root, rel))
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not os.path.exists(os.path.normpath(os.path.join(base, target))):
                findings.append(f"{rel}: broken relative link -> {target}")


# ----------------------------------------------------- 7. bench artifacts

CI_YML = ".github/workflows/ci.yml"
# Anchored on `.json`: env toggles (FASTKV_BENCH_QUICK) and derived
# outputs (BENCH_serve_trace.prom) must not match.
BENCH_NAME_RE = re.compile(r"\bBENCH_[A-Za-z0-9_]+\.json\b")
# A name only counts as *produced* when it appears inside a string
# literal (fs::write / str_or default) — doc-comment mentions don't.
BENCH_LITERAL_RE = re.compile(r'"[^"\n]*?(BENCH_[A-Za-z0-9_]+\.json)[^"\n]*"')


def check_bench_artifacts(root, findings):
    """CI's bench-summary steps (cat / assert / upload) and the Rust
    emitters drift independently: a renamed `fs::write` target leaves CI
    cat-ing a file nothing produces, and a new bench artifact nobody
    wires into CI silently vanishes from every run. Pin both directions.
    """
    ci = read(root, CI_YML)
    if ci is None:
        findings.append(f"missing {CI_YML}")
        return
    sources = rust_sources(root)
    produced_anywhere = {
        name
        for _rel, text in sources
        for name in BENCH_LITERAL_RE.findall(text)
    }

    # every artifact CI consumes is produced by some first-party source
    for name in sorted(set(BENCH_NAME_RE.findall(ci))):
        if name not in produced_anywhere:
            findings.append(
                f"{CI_YML} references `{name}` but no first-party Rust "
                "source writes it (searched string literals in rust/src, "
                "rust/tests, rust/benches, examples)"
            )

    # every artifact a CI-lane target produces is surfaced in CI
    # (benches + examples run in the rust lane; rust/src emitters such as
    # the eval subcommand are on-demand and exempt)
    for rel, text in sources:
        if not rel.startswith(("rust/benches/", "examples/")):
            continue
        for name in sorted(set(BENCH_LITERAL_RE.findall(text))):
            if name not in ci:
                findings.append(
                    f"{rel} writes `{name}` but {CI_YML} never cats, "
                    "asserts on, or uploads it"
                )


# ----------------------------------------------------------------- main

CHECKS = {
    "metrics": check_metrics,
    "artifacts": check_artifacts,
    "cli": check_cli,
    "lifecycle": check_lifecycle,
    "cargo": check_cargo,
    "links": check_links,
    "bench_artifacts": check_bench_artifacts,
}


def run(root, only=None):
    """Run the selected checks; returns the list of findings."""
    findings = []
    for name, fn in CHECKS.items():
        if only and name not in only:
            continue
        per = []
        fn(root, per)
        findings.extend(f"{name}: {msg}" for msg in per)
    return findings


def main(argv=None):
    default_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=default_root, help="repo root to check")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated subset of checks: " + ",".join(CHECKS),
    )
    ap.add_argument(
        "--list", action="store_true", help="list check names and exit"
    )
    args = ap.parse_args(argv)

    if args.list:
        print("\n".join(CHECKS))
        return 0

    only = None
    if args.only:
        only = set(args.only.split(","))
        unknown = only - set(CHECKS)
        if unknown:
            ap.error(f"unknown checks: {sorted(unknown)}")

    findings = run(args.root, only)
    for f in findings:
        print(f)
    n = len(only) if only else len(CHECKS)
    if findings:
        print(f"\n{len(findings)} finding(s) across {n} check(s)")
        return 1
    print(f"ok: {n} check(s) clean on {args.root}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
