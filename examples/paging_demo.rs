//! Paged KV-cache demo — runs with **no artifacts / no PJRT**: exercises
//! the block pool directly with synthetic compressed caches.
//!
//! Demonstrates the acceptance properties of the paging subsystem:
//!
//!  1. **Prefix reuse** — a batch of requests sharing a prompt allocates
//!     far fewer physical blocks than `tokens x requests`: full blocks are
//!     shared through the content-hash prefix cache.
//!  2. **Memory-aware admission + preemption** — with an under-provisioned
//!     pool, requests admit only when the allocator covers their
//!     post-compression budget, over-commit on decode growth, preempt back
//!     to the queue (releasing blocks) when the pool runs dry mid-decode,
//!     and *resume and finish* instead of aborting.
//!  3. **FastKV-aware compaction** — the same pressure run with
//!     block-granular compaction enabled: the policy's per-layer keep-sets
//!     release blocks in place, absorbing most of the pressure before
//!     preemption is needed.
//!
//! Run:  cargo run --release --example paging_demo -- [--requests 8]
//!       [--len 256] [--block-tokens 16] [--gen 160]

use fastkv::coordinator::kvcache::RequestCache;
use fastkv::coordinator::paging::{
    AppendResult, KvStore, PagedArena, PagingConfig,
};
use fastkv::coordinator::scheduler::{Action, AdmitOrder, Scheduler};
use fastkv::manifest::ModelMeta;
use fastkv::metrics::{names, Metrics};
use fastkv::tensor::HostTensor;
use fastkv::util::cli::Args;
use fastkv::util::rng::Rng;
use fastkv::PolicyCfg;

fn demo_meta() -> ModelMeta {
    ModelMeta {
        vocab_size: 256,
        d_model: 96,
        n_layers: 8,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 24,
        tsp_layer: 4,
        window: 8,
        pool_kernel: 7,
        max_train_len: 512,
    }
}

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Synthetic "compressed prefill" of a prompt: deterministic rows per
/// (prompt id, layer), FastKV-shaped per-layer lens (stage-1 layers retain
/// the full selection, stage-2 layers the TSP-propagated half).
fn compressed_cache(m: &ModelMeta, prompt_id: u64, len: usize) -> RequestCache {
    let re = m.n_kv_heads * m.head_dim;
    let mut rc = RequestCache::new(m);
    for l in 0..m.n_layers {
        let keep = if l < m.tsp_layer { len } else { len / 2 };
        let mut rng = Rng::new(prompt_id * 1000 + l as u64);
        rc.k[l] = (0..keep * re).map(|_| rng.f64() as f32).collect();
        rc.v[l] = (0..keep * re).map(|_| rng.f64() as f32).collect();
        rc.lens[l] = keep;
    }
    rc
}

fn decode_row(m: &ModelMeta, b: usize, seed: u64) -> HostTensor {
    let n = m.n_layers * b * m.n_kv_heads * m.head_dim;
    let mut rng = Rng::new(seed);
    HostTensor::new(
        vec![m.n_layers, b, m.n_kv_heads, m.head_dim],
        (0..n).map(|_| rng.f64() as f32).collect(),
    )
}

fn print_pool(tag: &str, ps: &fastkv::PoolStats) {
    println!(
        "  [{tag}] blocks {}/{} in use ({} cached, {} free) | prefix {} hits / {} misses ({:.1}%) | cow {} | evictions {} | alloc failures {}",
        ps.blocks_in_use,
        ps.blocks_total,
        ps.blocks_cached,
        ps.blocks_free,
        ps.prefix_hits,
        ps.prefix_misses,
        100.0 * ps.prefix_hit_rate(),
        ps.cow_copies,
        ps.evictions,
        ps.alloc_failures,
    );
}

struct PressureOutcome {
    preempted: u64,
    deferred: u64,
    compactions: u64,
    stats: fastkv::PoolStats,
}

/// Serve `requests` synthetic requests through a tight pool, optionally
/// compacting under pressure before preempting. Mirrors the server loop's
/// admission / compaction / preemption logic, minus the PJRT decode call.
#[allow(
    clippy::too_many_arguments,
    reason = "demo entry point mirroring the server loop's admission / \
              compaction / preemption knobs one-to-one; a config struct \
              here would just rename the CLI flags"
)]
fn pressure_run(
    m: &ModelMeta,
    requests: usize,
    len: usize,
    gen: usize,
    bt: usize,
    lanes: usize,
    pool_blocks: usize,
    compact: bool,
) -> PressureOutcome {
    let cap = len + gen + 1;
    let metrics = Metrics::default();
    let policy_cfg = PolicyCfg {
        kv_rate: 0.1,
        tsp_rate: 0.2,
        sinks: 4,
        filter_layer: m.tsp_layer.saturating_sub(1),
        use_pallas: false,
        prefill_budget: 0,
        decode_budget: 0,
        decode_window: m.window,
    };
    let cfg = PagingConfig {
        block_tokens: bt,
        num_blocks: Some(pool_blocks),
        prefix_cache: false,
        ..Default::default()
    };
    let mut pool = PagedArena::new(m, lanes, cap, cfg);
    // queue item: (id, cache, remaining decode steps)
    let mut sched: Scheduler<(usize, RequestCache, usize)> =
        Scheduler::new(lanes, AdmitOrder::Fcfs);
    for id in 0..requests {
        let rc = compressed_cache(m, 2000 + id as u64, len);
        sched.enqueue((id, rc, gen));
    }
    // active lane: (id, slot, cache, remaining)
    let mut active: Vec<(usize, usize, RequestCache, usize)> = Vec::new();
    let mut completed = 0usize;
    let mut step_no = 0u64;
    while completed < requests {
        step_no += 1;
        assert!(step_no < 10_000_000, "demo livelock");
        let admit_ok = sched
            .peek_next(|r| r.1.max_len())
            .map(|r| KvStore::can_admit(&pool, r.1.max_len(), r.2))
            .unwrap_or(true);
        match sched.next_action_mem(active.len(), admit_ok) {
            Action::Prefill => {
                let (id, rc, want) =
                    sched.pop_next(|r| r.1.max_len()).unwrap();
                match KvStore::admit(&mut pool, &rc) {
                    Some(slot) => active.push((id, slot, rc, want)),
                    None => {
                        metrics.inc(names::ADMIT_DEFERRED, 1);
                        sched.requeue_front((id, rc, want));
                    }
                }
            }
            Action::DecodeStep => {
                let step = decode_row(m, lanes, step_no);
                let mut i = 0;
                while i < active.len() {
                    let slot = active[i].1;
                    let mut res =
                        KvStore::append(&mut pool, slot, &step, &step);
                    if res == AppendResult::PoolExhausted && compact {
                        // FastKV-aware eviction: the policy's per-layer
                        // keep-sets drive block-granular compaction.
                        let lens = KvStore::layer_lens(&pool, slot);
                        let keep =
                            policy_cfg.compaction_keep(&lens, 0.5, m.window);
                        if KvStore::compact(&mut pool, slot, &keep) > 0 {
                            metrics.inc(names::COMPACTIONS, 1);
                            res = KvStore::append(&mut pool, slot, &step, &step);
                        }
                    }
                    match res {
                        AppendResult::Ok => {
                            active[i].3 -= 1;
                            i += 1;
                        }
                        AppendResult::CapacityExhausted => {
                            active[i].3 = 0;
                            i += 1;
                        }
                        AppendResult::PoolExhausted => {
                            // preempt: release blocks, resume later from
                            // the head of the queue
                            let (id, slot, rc, want) = active.swap_remove(i);
                            assert!(pool.release(slot));
                            metrics.inc(names::PREEMPTED, 1);
                            sched.requeue_front((id, rc, want));
                        }
                    }
                }
                let mut i = 0;
                while i < active.len() {
                    if active[i].3 == 0 {
                        let (_, slot, _, _) = active.swap_remove(i);
                        assert!(pool.release(slot));
                        completed += 1;
                    } else {
                        i += 1;
                    }
                }
            }
            Action::Idle => {
                assert!(
                    !active.is_empty() || admit_ok || sched.queue_len() == 0,
                    "pool can never fit the head request"
                );
            }
        }
    }
    let stats = pool.pool_stats();
    assert_eq!(completed, requests, "every request finished");
    assert_eq!(stats.blocks_in_use, 0, "all blocks returned");
    PressureOutcome {
        preempted: metrics.counter(names::PREEMPTED),
        deferred: metrics.counter(names::ADMIT_DEFERRED),
        compactions: metrics.counter(names::COMPACTIONS),
        stats,
    }
}

fn main() {
    let args = Args::from_env();
    let m = demo_meta();
    let requests = args.usize("requests", 8);
    let len = args.usize("len", 256);
    let bt = args.usize("block-tokens", 16);
    let gen = args.usize("gen", 160);

    // ---------------------------------------------------------------- 1
    println!("== 1. prefix reuse: {requests} requests sharing one prompt ==\n");
    let cap = len + gen + 1;
    let cfg = PagingConfig {
        block_tokens: bt,
        num_blocks: None,
        prefix_cache: true,
        ..Default::default()
    };
    let mut pool = PagedArena::new(&m, requests, cap, cfg.clone());
    let shared = compressed_cache(&m, 42, len);
    let per_request_blocks: usize =
        shared.lens.iter().map(|&n| ceil_div(n, bt)).sum();
    for _ in 0..requests {
        KvStore::admit(&mut pool, &shared).expect("worst-case pool admits");
    }
    let ps = pool.pool_stats();
    print_pool("shared prompt", &ps);
    println!(
        "  naive (tokens x requests): {} blocks; actually allocated: {} ({:.1}x saving)\n",
        per_request_blocks * requests,
        ps.blocks_in_use,
        (per_request_blocks * requests) as f64 / ps.blocks_in_use.max(1) as f64,
    );
    assert!(
        ps.blocks_in_use < per_request_blocks * requests,
        "prefix reuse must beat naive allocation"
    );

    // distinct prompts for contrast
    let mut pool2 = PagedArena::new(&m, requests, cap, cfg);
    for id in 0..requests {
        let rc = compressed_cache(&m, 1000 + id as u64, len);
        KvStore::admit(&mut pool2, &rc).expect("worst-case pool admits");
    }
    print_pool("distinct prompts", &pool2.pool_stats());

    // ---------------------------------------------------------------- 2/3
    // Pool sized so admission lets two requests in (covering their
    // post-compression budgets) but their decode growth over-commits it.
    let lanes = 4.min(requests.max(1));
    let admit_estimate = m.n_layers * ceil_div(len, bt) + m.n_layers;
    let initial_use: usize = (0..m.n_layers)
        .map(|l| {
            let keep = if l < m.tsp_layer { len } else { len / 2 };
            ceil_div(keep, bt)
        })
        .sum();
    let pool_blocks = initial_use + admit_estimate + m.n_layers;

    println!(
        "\n== 2. tight pool ({pool_blocks} blocks), preemption only: requests preempt + resume ==\n"
    );
    let out = pressure_run(&m, requests, len, gen, bt, lanes, pool_blocks, false);
    print_pool("preempt-only", &out.stats);
    println!(
        "  {requests} requests completed; {} preemptions, {} deferred admissions — none aborted",
        out.preempted, out.deferred,
    );
    assert!(
        out.preempted > 0,
        "the tight pool should have forced preemption"
    );

    println!(
        "\n== 3. same pool with FastKV-aware block compaction enabled ==\n"
    );
    let out2 = pressure_run(&m, requests, len, gen, bt, lanes, pool_blocks, true);
    print_pool("compacting", &out2.stats);
    println!(
        "  {requests} requests completed; {} compactions absorbed pressure, {} preemptions (vs {} without)",
        out2.compactions, out2.preempted, out.preempted,
    );
    assert!(out2.compactions > 0, "compaction should have engaged");
    assert!(
        out2.preempted <= out.preempted,
        "compaction must not increase preemptions"
    );
    println!("\nok: prefix reuse, admission control, preemption+resume, and compaction all verified");
}
