//! Needle-in-a-Haystack demo (Table 4 / Fig. 8 workload): runs the NIAH
//! grid for a chosen policy and prints the depth × length score matrix.
//!
//! Run:  cargo run --release --example niah_demo -- [--policy fastkv]
//!       [--lens 128,256,512] [--depths 5] [--samples 3]

use anyhow::Result;
use fastkv::coordinator::policies::PolicyCfg;
use fastkv::eval::runner::{run_niah, EvalConfig};
use fastkv::runtime::Runtime;
use fastkv::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let rt = Runtime::new(&fastkv::Manifest::default_dir())?;
    let man = rt.manifest.clone();
    let policy = args.str_or("policy", "fastkv").to_string();
    let lens = args.usize_list("lens", &[128, 256, 512]);
    let depths = args.usize("depths", 5);
    let mut cfg = PolicyCfg::default_for(&man);
    cfg.kv_rate = args.f64("kv-rate", 0.1);
    let ec = EvalConfig {
        policy_cfg: cfg,
        samples_per_task: args.usize("samples", 3),
        max_new: 12,
        seed: args.usize("seed", 0) as u64,
    };

    println!("NIAH grid — policy {policy}, kv_rate {}", ec.policy_cfg.kv_rate);
    let (total, grid) = run_niah(&rt, &man, &policy, &ec, &lens, depths)?;

    // depth rows × length columns
    print!("{:>8}", "depth\\len");
    for l in &lens {
        print!("{l:>8}");
    }
    println!();
    let mut depths_seen: Vec<f64> = grid.iter().map(|g| g.1).collect();
    depths_seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
    depths_seen.dedup();
    for d in depths_seen {
        print!("{d:>8.2}");
        for l in &lens {
            let s = grid
                .iter()
                .find(|(gl, gd, _)| gl == l && (gd - d).abs() < 1e-9)
                .map(|g| g.2)
                .unwrap_or(f64::NAN);
            print!("{s:>8.1}");
        }
        println!();
    }
    println!("\noverall score: {:.1}", total.score());
    Ok(())
}
