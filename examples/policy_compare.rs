//! Side-by-side policy comparison on one workload: accuracy, prefill
//! compute rate, KV cache size, and latency — a one-screen version of the
//! paper's headline claim (Table 1 + Table 2 rows).
//!
//! Run:  cargo run --release --example policy_compare -- [--len 512]
//!       [--samples 5] [--kv-rate 0.1]

use anyhow::Result;
use fastkv::coordinator::policies::{PolicyCfg, ALL_POLICIES};
use fastkv::eval::report::{method_label, table};
use fastkv::eval::runner::{run_sample, EvalConfig};
use fastkv::runtime::Runtime;
use fastkv::util::cli::Args;
use fastkv::util::rng::Rng;
use fastkv::workload;

fn main() -> Result<()> {
    let args = Args::from_env();
    let rt = Runtime::new(&fastkv::Manifest::default_dir())?;
    let man = rt.manifest.clone();
    let len = args.usize("len", 512);
    let samples = args.usize("samples", 5);
    let mut cfg = PolicyCfg::default_for(&man);
    cfg.kv_rate = args.f64("kv-rate", 0.1);
    cfg.tsp_rate = args.f64("tsp-rate", 0.2);
    let ec = EvalConfig {
        policy_cfg: cfg,
        samples_per_task: samples,
        max_new: 12,
        seed: args.usize("seed", 0) as u64,
    };

    println!(
        "policy comparison — len {len}, kv_rate {}, tsp_rate {}, {} samples\n",
        ec.policy_cfg.kv_rate, ec.policy_cfg.tsp_rate, samples
    );
    let mut rows = Vec::new();
    for m in ALL_POLICIES {
        let mut score = 0.0;
        let mut pf = 0.0;
        let mut dc = 0.0;
        let mut compute = 0usize;
        let mut cache = 0usize;
        let mut full_compute = 0usize;
        let mut full_cache = 0usize;
        let mut err = None;
        for i in 0..samples {
            let mut rng = Rng::new(1000 + i as u64);
            let s = workload::kv_recall(&mut rng, len, None, 2);
            match run_sample(&rt, &man, m, &ec.policy_cfg, &s, ec.max_new) {
                Ok((sc, st)) => {
                    score += sc;
                    pf += st.prefill_secs;
                    dc += st.decode_secs;
                    compute += st.compute_tokens;
                    cache += st.cache_elems;
                    full_compute += man.model.n_layers * st.prompt_tokens;
                    full_cache += 2
                        * man.model.n_layers
                        * st.prompt_tokens
                        * man.model.n_kv_heads
                        * man.model.head_dim;
                }
                Err(e) => {
                    err = Some(format!("{e}"));
                    break;
                }
            }
        }
        if let Some(e) = err {
            rows.push(vec![method_label(m).to_string(), e, String::new(),
                           String::new(), String::new(), String::new()]);
            continue;
        }
        let n = samples as f64;
        rows.push(vec![
            method_label(m).to_string(),
            format!("{:.0}", 100.0 * score / n),
            format!("{:.0}%", 100.0 * compute as f64 / full_compute as f64),
            format!(
                "{:.0}%",
                100.0 * (cache * man.model.n_kv_heads * man.model.head_dim)
                    as f64
                    / (full_cache * man.model.n_kv_heads * man.model.head_dim)
                        as f64
            ),
            format!("{:.1}", pf * 1e3 / n),
            format!("{:.1}", dc * 1e3 / n),
        ]);
        eprintln!("  {m} done");
    }
    println!(
        "{}",
        table(
            &["Method", "Acc", "Prefill", "KV", "prefill ms", "decode ms"],
            &rows
        )
    );
    Ok(())
}
