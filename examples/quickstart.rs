//! Quickstart: load artifacts, run a FastKV prefill+decode on a needle
//! prompt, and verify the L1 Pallas-kernel artifact agrees with the jnp
//! path end-to-end through PJRT.
//!
//! Run:  cargo run --release --example quickstart

use anyhow::Result;
use fastkv::coordinator::policies::{make_policy, Exec, PolicyCfg};
use fastkv::generate;
use fastkv::runtime::outputs::PrefillFullOut;
use fastkv::runtime::{In, Runtime};
use fastkv::tensor::HostTensorI32;
use fastkv::tokenizer::Tokenizer;
use fastkv::util::rng::Rng;
use fastkv::workload;

fn main() -> Result<()> {
    let rt = Runtime::new(&fastkv::Manifest::default_dir())?;
    let man = rt.manifest.clone();
    let tok = Tokenizer;
    println!("fastkv quickstart — model: {} layers, d={}, TSP layer {}",
             man.model.n_layers, man.model.d_model, man.model.tsp_layer);

    // 1. Generate with the FastKV policy on a synthetic needle prompt.
    let mut rng = Rng::new(42);
    let sample = workload::kv_recall(&mut rng, 256, None, 1);
    let ids = tok.encode(&sample.prompt);
    let cfg = PolicyCfg::default_for(&man);
    let policy = make_policy("fastkv")?;
    let out = generate(&rt, &man, policy.as_ref(), &cfg, &ids, 16)?;
    let pred = tok.decode_answer(&out.tokens);
    println!("\nneedle answer : {}", tok.render(&sample.answer));
    println!("generated     : {}", tok.render(&pred));
    println!(
        "prefill {:.1} ms | decode {:.1} ms ({} steps) | cache {} f32",
        out.stats.prefill_secs * 1e3,
        out.stats.decode_secs * 1e3,
        out.stats.decode_steps,
        out.stats.cache_elems
    );

    // 2. Prove the Pallas-kernel artifact (L1 on the hot path) matches the
    //    jnp-path artifact through the whole AOT+PJRT pipeline.
    let n = man.buckets.pallas_n;
    let mut rng = Rng::new(7);
    let s2 = workload::kv_recall(&mut rng, n, None, 0);
    let ids2: Vec<i32> = tok.encode(&s2.prompt);
    let toks = HostTensorI32::new(vec![n], ids2.clone());
    let jnp = PrefillFullOut::from_vec(Exec::run(
        &rt,
        &format!("prefill_full_{n}"),
        vec![toks.clone().into(), In::scalar_i32(n as i32)],
    )?);
    let pallas = PrefillFullOut::from_vec(Exec::run(
        &rt,
        &format!("prefill_pallas_{n}"),
        vec![toks.into(), In::scalar_i32(n as i32)],
    )?);
    let max_diff = jnp
        .logits
        .data
        .iter()
        .zip(&pallas.logits.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("\npallas vs jnp artifact: max logit diff = {max_diff:.2e}");
    assert!(max_diff < 1e-3, "Pallas artifact disagrees with jnp path");
    println!("quickstart OK");
    Ok(())
}
