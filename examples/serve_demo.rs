//! Serving demo: spin up the continuous-batching server with the FastKV
//! policy, fire concurrent client requests at it, and report
//! throughput / TTFT / e2e latency percentiles.
//!
//! Run:  cargo run --release --example serve_demo -- [--clients 8]
//!       [--len 256] [--policy fastkv] [--batch 4]
//!       [--trace-out F.json] [--metrics-out F.json] [--metrics-every N]
//!
//! Multi-tenant contention: `--tenants T --quota-blocks R` serves a
//! *weighted* workload — tenant 0 submits half the clients (the heavy
//! tenant), the rest round-robin across tenants 1..T — with every tenant
//! guaranteed a reserved floor of R pool blocks. Pair with
//! `--pool-blocks` to make the pool tight enough that the quota matters;
//! per-tenant completions / preemptions / block charges are reported at
//! the end.
//!
//! Observability smoke mode (no compiled artifacts needed — what CI
//! runs): `--sim` drives the real admit / preempt / swap-resume /
//! finish machinery with a synthetic policy and decode loop, tracing
//! enabled, then writes the JSON metrics snapshot
//! (`BENCH_serve_trace.json` + `.prom` sibling) and the Chrome trace,
//! validates every request's lifecycle ordering, and asserts the phase
//! histograms are non-empty.

use anyhow::Result;
use fastkv::coordinator::policies::PolicyCfg;
use fastkv::coordinator::scheduler::AdmitOrder;
use fastkv::coordinator::server::{Server, ServerConfig};
use fastkv::metrics::names;
use fastkv::tokenizer::Tokenizer;
use fastkv::util::cli::Args;
use fastkv::util::rng::Rng;
use fastkv::workload;
use fastkv::{ObsConfig, TenantId, TenantQuota};

fn main() -> Result<()> {
    let args = Args::from_env();
    if args.has("sim") {
        return sim::run(&args);
    }
    let dir = fastkv::Manifest::default_dir();
    let man = fastkv::Manifest::load(&dir)?;
    let policy = args.str_or("policy", "fastkv").to_string();
    let n_clients = args.usize("clients", 8);
    let len = args.usize("len", 256);
    let max_new = args.usize("gen", 16);

    let mut policy_cfg = PolicyCfg::default_for(&man);
    policy_cfg.kv_rate = args.f64("kv-rate", 0.1);
    // Paged KV backend (the default); --pool-blocks under-provisions the
    // block pool to force memory-aware admission + preemption.
    let mut paging = fastkv::PagingConfig::default();
    paging.block_tokens = args.usize("block-tokens", paging.block_tokens);
    if let Some(nb) = args.get("pool-blocks") {
        paging.num_blocks =
            Some(nb.parse().expect("--pool-blocks: not a number"));
    }
    // Host swap budget for preempted lanes (MiB); 0 = recompute-resume.
    paging.swap_bytes =
        args.usize("swap-mb", paging.swap_bytes >> 20) << 20;
    // --precision f32|f16|int8: KV codec for the resident slab and the
    // default swap tier (per-tenant overrides via TenantQuota::precision).
    if let Some(p) = args.get("precision") {
        paging.precision = fastkv::KvCodec::parse(p)
            .map_err(|e| anyhow::anyhow!("--precision: {e}"))?;
    }
    // --tenants T + --quota-blocks R: reserved floor of R blocks per
    // tenant (quotas only engage when both are set).
    let tenants = args.usize("tenants", 1).max(1);
    let quota_blocks = args.usize("quota-blocks", 0);
    if tenants > 1 && quota_blocks > 0 {
        paging.tenant_quotas = (0..tenants as u32)
            .map(|t| (TenantId(t), TenantQuota::reserved(quota_blocks)))
            .collect();
    }
    // Observability: --trace-out implies tracing on; --metrics-out adds
    // the JSON snapshot (+ Prometheus sibling), re-exported every
    // --metrics-every serve-loop iterations and on shutdown.
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let default_events = if trace_out.is_some() { 65536 } else { 0 };
    let obs = ObsConfig {
        trace_events: args.usize("trace-events", default_events),
        trace_out,
        metrics_out: args
            .get("metrics-out")
            .map(std::path::PathBuf::from),
        export_every: args.usize("metrics-every", 0),
    };
    let cfg = ServerConfig {
        artifact_dir: dir,
        policy: policy.clone(),
        policy_cfg,
        decode_batch: args.usize("batch", 4),
        max_new,
        max_prompt: len,
        order: AdmitOrder::Fcfs,
        paging: Some(paging),
        obs,
    };
    println!("starting server: policy={policy} batch={} len={len}", cfg.decode_batch);
    let server = Server::spawn(cfg)?;
    let handle = server.handle();
    let tok = Tokenizer;

    let t0 = std::time::Instant::now();
    // Submit all requests up front (closed-loop offered load), then join.
    // Weighted tenant assignment: tenant 0 (heavy) submits half the
    // clients, the rest round-robin across tenants 1..T.
    let tenant_of = |i: usize| -> TenantId {
        if tenants <= 1 {
            TenantId::DEFAULT
        } else if i < n_clients / 2 {
            TenantId(0)
        } else {
            TenantId(1 + ((i - n_clients / 2) % (tenants - 1)) as u32)
        }
    };
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..n_clients {
        let mut rng = Rng::new(7000 + i as u64);
        let s = workload::kv_recall(&mut rng, len, None, 1);
        let ids = tok.encode(&s.prompt);
        let (id, rx) = handle.submit_for(ids, max_new, tenant_of(i))?;
        expected.push((id, s.answer));
        rxs.push(rx);
    }
    let mut correct = 0;
    let mut total_tokens = 0usize;
    for (rx, (_, answer)) in rxs.into_iter().zip(&expected) {
        let resp = rx.recv()?;
        if let Some(e) = resp.error {
            println!("request {} error: {e}", resp.id);
            continue;
        }
        let pred = tok.decode_answer(&resp.tokens);
        total_tokens += resp.tokens.len();
        if &pred == answer {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n{n_clients} requests in {wall:.2}s  \
              ({:.1} tok/s out, {correct}/{n_clients} answers correct)",
             total_tokens as f64 / wall);
    // Join the serving thread so the shutdown export has flushed.
    drop(server);
    println!(
        "\nblock pool: peak {}/{} blocks in use, prefix hit rate {:.1}%, \
         {} preempted, {} compactions",
        handle.metrics.gauge(names::POOL_BLOCKS_IN_USE_PEAK),
        handle.metrics.gauge(names::POOL_BLOCKS_TOTAL),
        100.0 * handle.metrics.gauge(names::POOL_PREFIX_HIT_RATE),
        handle.metrics.counter(names::PREEMPTED),
        handle.metrics.counter(names::COMPACTIONS),
    );
    println!(
        "swap: {} out / {} in, {} recompute fallbacks, {} prefills \
         recomputed",
        handle.metrics.counter(names::SWAP_OUTS),
        handle.metrics.counter(names::SWAP_INS),
        handle.metrics.counter(names::SWAP_FALLBACK_RECOMPUTE)
            + handle.metrics.counter(names::SWAP_REFUSED),
        handle.metrics.counter(names::PREFILL_RECOMPUTED),
    );
    if tenants > 1 {
        println!(
            "\nper-tenant (quota floor {} blocks{}):",
            quota_blocks,
            if quota_blocks == 0 { " — quotas OFF" } else { "" }
        );
        for t in 0..tenants as u32 {
            let t = TenantId(t);
            println!(
                "  tenant {t}: {} completed, {} preempted, {} rejected, \
                 {} blocks held at exit, quota denials pool-wide {}",
                handle.metrics.counter(&names::tenant_completed(t)),
                handle.metrics.counter(&names::tenant_preempted(t)),
                handle.metrics.counter(&names::tenant_rejected(t)),
                handle.metrics.gauge(&names::tenant_blocks_held(t)),
                handle.metrics.gauge(names::POOL_QUOTA_DENIALS),
            );
        }
    }
    println!("\nserver metrics:\n{}", handle.metrics.report());
    let flights = fastkv::obs::flight_text(handle.metrics.tracer());
    if !flights.is_empty() {
        println!("flight recorder:\n{flights}");
    }
    Ok(())
}

/// Artifact-free observability smoke: the same sim harness idiom as
/// `rust/tests/paging.rs` (synthetic policy + deterministic decode rows)
/// driven through the REAL serving functions — `admit`, `preempt`
/// (swap-to-host), `try_resume`, `finish`, `reject`, `advance_lane` —
/// with lifecycle tracing on.
mod sim {
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicUsize, Ordering};

    use anyhow::Result;
    use fastkv::coordinator::decode::{advance_lane, LaneAdvance};
    use fastkv::coordinator::kvcache::RequestCache;
    use fastkv::coordinator::paging::KvStore;
    use fastkv::coordinator::policies::{
        Exec, Policy, PolicyCfg, PrefillOutcome,
    };
    use fastkv::coordinator::scheduler::{AdmitOrder, Scheduler};
    use fastkv::coordinator::server::{
        admit, finish, preempt, reject, try_resume, Active, AdmitFail,
        Request, Resume, ServerConfig,
    };
    use fastkv::manifest::{Buckets, Manifest, ModelMeta};
    use fastkv::metrics::{names, Metrics};
    use fastkv::obs::trace::{validate_lifecycle, EventKind, NO_LANE};
    use fastkv::runtime::outputs::DecodeOut;
    use fastkv::tensor::HostTensor;
    use fastkv::util::cli::Args;
    use fastkv::util::rng::Rng;
    use fastkv::{PagedArena, PagingConfig, TenantId};

    fn sim_meta() -> ModelMeta {
        ModelMeta {
            vocab_size: 256,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            head_dim: 2,
            tsp_layer: 1,
            window: 2,
            pool_kernel: 3,
            max_train_len: 64,
        }
    }

    fn sim_manifest(limit: usize) -> Manifest {
        Manifest {
            dir: std::path::PathBuf::from("/tmp"),
            model: sim_meta(),
            n_params: 1,
            kernel: "jnp".into(),
            buckets: Buckets {
                prefill_ns: vec![limit],
                stage1_ns: vec![limit],
                stage2_ns: vec![limit],
                pyramid_ns: vec![limit],
                decode_batches: vec![1, 2, 4],
                decode_caps: vec![64],
                sweep_n: 64,
                sweep_nt: 16,
                pallas_n: limit,
                max_gen: 16,
                block_tokens: 2,
                shard_counts: vec![],
            },
            artifacts: std::collections::BTreeMap::new(),
        }
    }

    /// Deterministic KV row for (layer, position, token) — shared by the
    /// sim prefill and the sim decode loop.
    fn sim_kv_row(l: usize, pos: usize, token: i32, re: usize) -> Vec<f32> {
        (0..re)
            .map(|i| {
                (l as f32) * 1000.0
                    + (pos as f32) * 10.0
                    + (token as f32) * 0.125
                    + (i as f32) * 0.0625
            })
            .collect()
    }

    /// Deterministic next token from the full sequence (never END, so
    /// requests run to `max_new`).
    fn sim_next_token(seq: &[i32]) -> i32 {
        let mut h = 0xcbf29ce484222325u64;
        for &t in seq {
            h ^= t as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        4 + (h % 200) as i32
    }

    /// Stand-in policy: prefill materializes exactly the KV rows the sim
    /// decode loop would have appended for the sequence.
    struct SimPolicy {
        calls: AtomicUsize,
    }

    impl Policy for SimPolicy {
        fn name(&self) -> &'static str {
            "sim"
        }

        fn prefill(
            &self,
            _ex: &dyn Exec,
            man: &Manifest,
            tokens: &[i32],
            _cfg: &PolicyCfg,
        ) -> anyhow::Result<PrefillOutcome> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            let m = &man.model;
            let re = m.n_kv_heads * m.head_dim;
            let mut cache = RequestCache::new(m);
            for l in 0..m.n_layers {
                let mut k = Vec::with_capacity(tokens.len() * re);
                for (pos, &t) in tokens.iter().enumerate() {
                    k.extend_from_slice(&sim_kv_row(l, pos, t, re));
                }
                cache.v[l] = k.iter().map(|x| -x).collect();
                cache.k[l] = k;
                cache.lens[l] = tokens.len();
            }
            Ok(PrefillOutcome {
                first_token: sim_next_token(tokens),
                cache,
                next_pos: tokens.len(),
                final_h: Vec::new(),
                compute_tokens: tokens.len() * m.n_layers,
            })
        }
    }

    /// Executor stub: the sim policy never runs artifacts.
    struct NoExec;

    impl Exec for NoExec {
        fn run(
            &self,
            _name: &str,
            _inputs: Vec<fastkv::runtime::In>,
        ) -> anyhow::Result<Vec<HostTensor>> {
            anyhow::bail!("sim mode never executes artifacts")
        }
    }

    /// One synthetic decode round over the active lanes through the real
    /// `advance_lane` + `Active::apply`, timed as a decode step.
    fn decode_round(
        pa: &mut PagedArena,
        active: &mut [Active],
        prompts: &HashMap<u64, Vec<i32>>,
        metrics: &Metrics,
    ) {
        let m = sim_meta();
        let re = m.n_kv_heads * m.head_dim;
        let b = KvStore::slots(pa);
        let t0 = std::time::Instant::now();
        for a in active.iter_mut() {
            if a.is_done() {
                continue;
            }
            let mut k_new = HostTensor::zeros(vec![
                m.n_layers,
                b,
                m.n_kv_heads,
                m.head_dim,
            ]);
            let mut v_new = k_new.clone();
            for l in 0..m.n_layers {
                let row = sim_kv_row(l, a.pos(), a.cur(), re);
                let base = (l * b + a.slot()) * re;
                k_new.data[base..base + re].copy_from_slice(&row);
                for (i, x) in row.iter().enumerate() {
                    v_new.data[base + i] = -x;
                }
            }
            let mut seq = prompts[&a.request_id()].clone();
            seq.extend_from_slice(a.tokens());
            let next = sim_next_token(&seq);
            let mut logits = HostTensor::zeros(vec![b, m.vocab_size]);
            logits.data[a.slot() * m.vocab_size + next as usize] = 1.0;
            let out = DecodeOut { logits, k_new, v_new };
            let adv = advance_lane(pa, a.slot(), &out, None);
            assert!(
                matches!(adv, LaneAdvance::Next { .. }),
                "sim decode hit {adv:?}"
            );
            metrics.tracer().record(
                a.request_id(),
                a.tenant(),
                a.slot() as i32,
                EventKind::DecodeStep {
                    step: a.pos() as u32,
                    tokens_out: a.tokens().len() as u32,
                },
            );
            a.apply(adv);
        }
        metrics
            .observe(names::DECODE_STEP_SECS, t0.elapsed().as_secs_f64());
    }

    pub fn run(args: &Args) -> Result<()> {
        let n = args.usize("clients", 6);
        let len = args.usize("len", 24);
        let max_new = args.usize("gen", 8);
        let preempt_at = args.usize("preempt-at", 3);
        let lanes = args.usize("batch", 2);
        let metrics_out = std::path::PathBuf::from(
            args.str_or("metrics-out", "BENCH_serve_trace.json"),
        );
        let trace_out = std::path::PathBuf::from(
            args.str_or("trace-out", "BENCH_serve_chrome.json"),
        );

        let man = sim_manifest(64);
        let m = sim_meta();
        let policy = SimPolicy { calls: AtomicUsize::new(0) };
        let metrics = Metrics::default();
        metrics.tracer().enable(args.usize("trace-events", 4096));
        let cfg = ServerConfig {
            artifact_dir: std::path::PathBuf::from("/tmp"),
            policy: "sim".into(),
            policy_cfg: PolicyCfg {
                kv_rate: 1.0,
                tsp_rate: 1.0,
                sinks: 1,
                filter_layer: 0,
                use_pallas: false,
                prefill_budget: 0,
                decode_budget: 0,
                decode_window: m.window,
            },
            decode_batch: lanes,
            max_new,
            max_prompt: 32,
            order: AdmitOrder::Fcfs,
            paging: Some(PagingConfig::default()),
            obs: Default::default(),
        };
        let pcfg = PagingConfig {
            block_tokens: 2,
            prefix_cache: false,
            swap_bytes: 1 << 20,
            ..Default::default()
        };
        let mut pa = PagedArena::new(&m, lanes, 64, pcfg);
        let mut sched: Scheduler<Request> =
            Scheduler::new(lanes, AdmitOrder::Fcfs);
        let tracer = metrics.tracer();

        // Submit n requests under two tenants, plus one oversized request
        // that must be rejected (exercises the flight recorder).
        let mut prompts: HashMap<u64, Vec<i32>> = HashMap::new();
        let mut rxs = Vec::new();
        let mut ids = Vec::new();
        for i in 0..n as u64 {
            let mut rng = Rng::new(9000 + i);
            let p: Vec<i32> =
                (0..len).map(|_| 4 + rng.below(200) as i32).collect();
            let tenant = TenantId((i % 2) as u32);
            let (req, rx) =
                Request::synthetic_for(i, p.clone(), max_new, tenant);
            tracer.record(
                i,
                tenant,
                NO_LANE,
                EventKind::Submit { prompt_tokens: p.len() as u32 },
            );
            prompts.insert(i, p);
            rxs.push(rx);
            ids.push(i);
            sched.enqueue(req);
        }
        let reject_id = n as u64;
        let (big, big_rx) = Request::synthetic(
            reject_id,
            vec![5; cfg.max_prompt + 1],
            max_new,
        );
        tracer.record(
            reject_id,
            TenantId::DEFAULT,
            NO_LANE,
            EventKind::Submit {
                prompt_tokens: (cfg.max_prompt + 1) as u32,
            },
        );
        sched.enqueue(big);

        let mut active: Vec<Active> = Vec::new();
        let mut preempted_once = vec![false; n];
        let mut done = 0usize;
        let mut guard = 0;
        while done < n + 1 {
            guard += 1;
            assert!(guard < 10_000, "sim serve loop livelocked");
            // admission / resume phase (lane-limited, so requests queue)
            while active.len() < lanes && sched.queue_len() > 0 {
                let req = sched.pop_next(|r| r.prompt.len()).unwrap();
                match try_resume(req, &mut pa, &metrics) {
                    Resume::Restored(a) => active.push(a),
                    Resume::Busy(req) => {
                        sched.requeue_front(req);
                        break;
                    }
                    Resume::Recompute(req) => {
                        match admit(
                            &NoExec, &man, &policy, &cfg, req, &mut pa,
                            &metrics,
                        ) {
                            Ok(a) => active.push(a),
                            Err(AdmitFail::Defer(req)) => {
                                sched.requeue_front(req);
                                break;
                            }
                            Err(AdmitFail::Reject(req, e)) => {
                                reject(
                                    req,
                                    &mut pa,
                                    &metrics,
                                    format!("{e:#}"),
                                );
                                done += 1;
                            }
                        }
                    }
                }
            }
            decode_round(&mut pa, &mut active, &prompts, &metrics);
            // retire through the real finish (releases the lane, sends
            // the response, observes TTFT/e2e)
            let mut j = 0;
            while j < active.len() {
                if active[j].is_done()
                    || active[j].tokens().len() >= max_new
                {
                    let a = active.remove(j);
                    finish(a, &mut pa, &metrics);
                    done += 1;
                } else {
                    j += 1;
                }
            }
            // token-progress preemption trigger, once per request
            let mut j = 0;
            while j < active.len() {
                let id = active[j].request_id() as usize;
                if id < n
                    && !preempted_once[id]
                    && active[j].tokens().len() >= preempt_at
                {
                    preempted_once[id] = true;
                    preempt(&mut active, j, &mut pa, &mut sched, &metrics);
                } else {
                    j += 1;
                }
            }
        }

        // Every normal request completed with tokens and a measured TTFT;
        // the oversized one was rejected without a fake TTFT.
        for rx in rxs {
            let resp = rx.recv()?;
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(resp.tokens.len(), max_new);
            assert!(resp.ttft_secs.is_some(), "completed without TTFT");
        }
        let rejected = big_rx.recv()?;
        assert!(rejected.error.is_some(), "oversized request not rejected");
        assert!(rejected.ttft_secs.is_none(), "reject invented a TTFT");

        // Lifecycle-ordering invariant over every traced request.
        for &id in ids.iter().chain([&reject_id]) {
            let evs = tracer.events_for(id, usize::MAX);
            assert!(!evs.is_empty(), "request {id} left no events");
            if let Err(e) = validate_lifecycle(&evs) {
                panic!("request {id} lifecycle violated: {e}\n{evs:#?}");
            }
        }

        // Phase timings present and non-empty — the CI smoke assertion.
        for h in [
            names::QUEUE_WAIT_SECS,
            names::PREFILL_SECS,
            names::DECODE_STEP_SECS,
            names::SWAP_OUT_SECS,
            names::SWAP_IN_SECS,
            names::TTFT_SECS,
            names::E2E_SECS,
        ] {
            assert!(
                metrics.histogram(h).count() > 0,
                "phase histogram {h} is empty"
            );
        }
        assert!(
            metrics.counter(names::SWAP_OUTS) > 0
                && metrics.counter(names::SWAP_INS) > 0,
            "sim run exercised no swap-out/swap-in"
        );
        // The reject filed a flight-recorder incident carrying history.
        let incidents = tracer.incidents();
        assert!(
            incidents
                .iter()
                .any(|i| i.req == reject_id && !i.history.is_empty()),
            "reject filed no flight-recorder incident"
        );

        // Export plane: JSON snapshot (+ .prom sibling) and Chrome trace.
        fastkv::obs::write_json_snapshot(&metrics, &metrics_out)?;
        fastkv::obs::write_prometheus(
            &metrics,
            &metrics_out.with_extension("prom"),
        )?;
        fastkv::obs::write_chrome_trace(tracer, &trace_out)?;
        // Round-trip check: the snapshot parses and carries the phase
        // histograms + per-tenant series.
        let raw = std::fs::read_to_string(&metrics_out)?;
        let v = fastkv::util::json::Value::parse(&raw)?;
        let hists = v.req("histograms");
        for h in [names::QUEUE_WAIT_SECS, names::DECODE_STEP_SECS] {
            assert!(
                hists.req(h).req("count").as_f64().unwrap_or(0.0) > 0.0,
                "snapshot missing phase histogram {h}"
            );
        }
        assert!(
            v.req("counters")
                .req(&names::tenant_completed(TenantId(1)))
                .as_f64()
                .unwrap_or(0.0)
                > 0.0,
            "snapshot missing per-tenant series"
        );

        println!(
            "sim smoke OK: {} requests ({} rejected), {} policy calls, \
             {} swap-outs, {} trace events ({} dropped)",
            n + 1,
            metrics.counter(names::REJECTED),
            policy.calls.load(Ordering::Relaxed),
            metrics.counter(names::SWAP_OUTS),
            tracer.len(),
            tracer.dropped(),
        );
        println!("{}", metrics.report());
        let flights = fastkv::obs::flight_text(tracer);
        if !flights.is_empty() {
            println!("flight recorder:\n{flights}");
        }
        println!(
            "wrote {} (+ .prom) and {}",
            metrics_out.display(),
            trace_out.display()
        );
        Ok(())
    }
}
