//! Serving demo: spin up the continuous-batching server with the FastKV
//! policy, fire concurrent client requests at it, and report
//! throughput / TTFT / e2e latency percentiles.
//!
//! Run:  cargo run --release --example serve_demo -- [--clients 8]
//!       [--len 256] [--policy fastkv] [--batch 4]
//!
//! Multi-tenant contention: `--tenants T --quota-blocks R` serves a
//! *weighted* workload — tenant 0 submits half the clients (the heavy
//! tenant), the rest round-robin across tenants 1..T — with every tenant
//! guaranteed a reserved floor of R pool blocks. Pair with
//! `--pool-blocks` to make the pool tight enough that the quota matters;
//! per-tenant completions / preemptions / block charges are reported at
//! the end.

use anyhow::Result;
use fastkv::coordinator::policies::PolicyCfg;
use fastkv::metrics::names;
use fastkv::coordinator::scheduler::AdmitOrder;
use fastkv::coordinator::server::{Server, ServerConfig};
use fastkv::tokenizer::Tokenizer;
use fastkv::util::cli::Args;
use fastkv::util::rng::Rng;
use fastkv::workload;
use fastkv::{TenantId, TenantQuota};

fn main() -> Result<()> {
    let args = Args::from_env();
    let dir = fastkv::Manifest::default_dir();
    let man = fastkv::Manifest::load(&dir)?;
    let policy = args.str_or("policy", "fastkv").to_string();
    let n_clients = args.usize("clients", 8);
    let len = args.usize("len", 256);
    let max_new = args.usize("gen", 16);

    let mut policy_cfg = PolicyCfg::default_for(&man);
    policy_cfg.kv_rate = args.f64("kv-rate", 0.1);
    // Paged KV backend (the default); --pool-blocks under-provisions the
    // block pool to force memory-aware admission + preemption.
    let mut paging = fastkv::PagingConfig::default();
    paging.block_tokens = args.usize("block-tokens", paging.block_tokens);
    if let Some(nb) = args.get("pool-blocks") {
        paging.num_blocks =
            Some(nb.parse().expect("--pool-blocks: not a number"));
    }
    // Host swap budget for preempted lanes (MiB); 0 = recompute-resume.
    paging.swap_bytes =
        args.usize("swap-mb", paging.swap_bytes >> 20) << 20;
    // --tenants T + --quota-blocks R: reserved floor of R blocks per
    // tenant (quotas only engage when both are set).
    let tenants = args.usize("tenants", 1).max(1);
    let quota_blocks = args.usize("quota-blocks", 0);
    if tenants > 1 && quota_blocks > 0 {
        paging.tenant_quotas = (0..tenants as u32)
            .map(|t| (TenantId(t), TenantQuota::reserved(quota_blocks)))
            .collect();
    }
    let cfg = ServerConfig {
        artifact_dir: dir,
        policy: policy.clone(),
        policy_cfg,
        decode_batch: args.usize("batch", 4),
        max_new,
        max_prompt: len,
        order: AdmitOrder::Fcfs,
        paging: Some(paging),
    };
    println!("starting server: policy={policy} batch={} len={len}", cfg.decode_batch);
    let server = Server::spawn(cfg)?;
    let handle = server.handle();
    let tok = Tokenizer;

    let t0 = std::time::Instant::now();
    // Submit all requests up front (closed-loop offered load), then join.
    // Weighted tenant assignment: tenant 0 (heavy) submits half the
    // clients, the rest round-robin across tenants 1..T.
    let tenant_of = |i: usize| -> TenantId {
        if tenants <= 1 {
            TenantId::DEFAULT
        } else if i < n_clients / 2 {
            TenantId(0)
        } else {
            TenantId(1 + ((i - n_clients / 2) % (tenants - 1)) as u32)
        }
    };
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..n_clients {
        let mut rng = Rng::new(7000 + i as u64);
        let s = workload::kv_recall(&mut rng, len, None, 1);
        let ids = tok.encode(&s.prompt);
        let (id, rx) = handle.submit_for(ids, max_new, tenant_of(i))?;
        expected.push((id, s.answer));
        rxs.push(rx);
    }
    let mut correct = 0;
    let mut total_tokens = 0usize;
    for (rx, (_, answer)) in rxs.into_iter().zip(&expected) {
        let resp = rx.recv()?;
        if let Some(e) = resp.error {
            println!("request {} error: {e}", resp.id);
            continue;
        }
        let pred = tok.decode_answer(&resp.tokens);
        total_tokens += resp.tokens.len();
        if &pred == answer {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n{n_clients} requests in {wall:.2}s  \
              ({:.1} tok/s out, {correct}/{n_clients} answers correct)",
             total_tokens as f64 / wall);
    println!(
        "\nblock pool: peak {}/{} blocks in use, prefix hit rate {:.1}%, \
         {} preempted, {} compactions",
        handle.metrics.gauge("pool_blocks_in_use_peak"),
        handle.metrics.gauge("pool_blocks_total"),
        100.0 * handle.metrics.gauge("pool_prefix_hit_rate"),
        handle.metrics.counter("preempted"),
        handle.metrics.counter("compactions"),
    );
    println!(
        "swap: {} out / {} in, {} recompute fallbacks, {} prefills \
         recomputed",
        handle.metrics.counter(names::SWAP_OUTS),
        handle.metrics.counter(names::SWAP_INS),
        handle.metrics.counter(names::SWAP_FALLBACK_RECOMPUTE)
            + handle.metrics.counter(names::SWAP_REFUSED),
        handle.metrics.counter(names::PREFILL_RECOMPUTED),
    );
    if tenants > 1 {
        println!(
            "\nper-tenant (quota floor {} blocks{}):",
            quota_blocks,
            if quota_blocks == 0 { " — quotas OFF" } else { "" }
        );
        for t in 0..tenants as u32 {
            let t = TenantId(t);
            println!(
                "  tenant {t}: {} completed, {} preempted, {} rejected, \
                 {} blocks held at exit, quota denials pool-wide {}",
                handle.metrics.counter(&names::tenant_completed(t)),
                handle.metrics.counter(&names::tenant_preempted(t)),
                handle.metrics.counter(&names::tenant_rejected(t)),
                handle.metrics.gauge(&names::tenant_blocks_held(t)),
                handle.metrics.gauge(names::POOL_QUOTA_DENIALS),
            );
        }
    }
    println!("\nserver metrics:\n{}", handle.metrics.report());
    Ok(())
}
