"""L2 correctness: model entry points, stage equivalences, decode
consistency, pyramid schedule, TSP selection."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import TEST, ModelConfig
from compile import model as M
from compile import layers as L
from compile.params import (
    init_params, flatten, unflatten, n_params, param_specs,
)

CFG = TEST


@pytest.fixture(scope="module")
def flat():
    return jnp.asarray(flatten(init_params(CFG, 1), CFG))


def _toks(rng, n):
    return jnp.asarray(rng.integers(7, 120, n), jnp.int32)


class TestParams:
    def test_roundtrip(self):
        p = init_params(CFG, 3)
        f = flatten(p, CFG)
        p2 = unflatten(jnp.asarray(f), CFG)
        for name, shape in param_specs(CFG):
            np.testing.assert_array_equal(
                p[name], np.asarray(p2[name]), err_msg=name
            )

    def test_count_matches_specs(self):
        assert n_params(CFG) == sum(
            int(np.prod(s)) for _, s in param_specs(CFG)
        )


class TestRope:
    def test_preserves_norm(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(16, 2, 8)).astype(np.float32))
        pos = jnp.arange(16, dtype=jnp.int32)
        y = L.rope(x, pos, 10_000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1),
            rtol=1e-5,
        )

    def test_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.normal(size=(1, 1, 8)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(1, 1, 8)).astype(np.float32))

        def dot(i, j):
            qr = L.rope(q, jnp.asarray([i], jnp.int32), 10_000.0)
            kr = L.rope(k, jnp.asarray([j], jnp.int32), 10_000.0)
            return float(jnp.sum(qr * kr))

        assert dot(5, 3) == pytest.approx(dot(12, 10), rel=1e-4)
        assert dot(9, 0) == pytest.approx(dot(20, 11), rel=1e-4)


class TestStageEquivalence:
    def test_stage12_equals_full(self, flat):
        """With the full token set propagated, the two-stage prefill is
        bit-for-bit the same computation as prefill_full."""
        rng = np.random.default_rng(2)
        n = 64
        toks = _toks(rng, n)
        nv = jnp.int32(n)
        lg, k, v, win, acc, fh = M.prefill_full(flat, toks, nv, cfg=CFG)
        hid, k1, v1, w1, a1 = M.prefill_stage1(flat, toks, nv, cfg=CFG)
        pos = jnp.arange(n, dtype=jnp.int32)
        lg2, k2, v2, w2, a2, fh2 = M.prefill_stage2(
            flat, hid, pos, nv, cfg=CFG
        )
        t = CFG.tsp_layer
        np.testing.assert_allclose(lg, lg2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(fh, fh2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(k[:t], k1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(k[t:], k2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(win[:t], w1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(acc[t:], a2, rtol=1e-4, atol=1e-4)

    def test_chunked_stage1_bit_identical(self, flat):
        """Chunked stage 1 ≡ monolithic stage 1, *bitwise* (the chunked
        prefill tentpole pin).

        Drives ``prefill_stage1_chunk`` exactly the way the rust chunked
        driver does — spans from the same rule as ``policies::chunk_spans``
        (every non-final chunk completely full, final chunk covering the
        whole observation window), chunk K/V copied back into a host-side
        buffer, win taken from the final chunk — and demands exact
        equality on hidden states, stage-1 KV, and window scores.
        """

        def spans(n, chunk, window):
            out, pos = [], 0
            while pos < n:
                remaining = n - pos
                if remaining <= chunk:
                    ln = remaining
                elif remaining - chunk < window:
                    ln = remaining - window
                else:
                    ln = chunk
                out.append((pos, ln))
                pos += ln
            return out

        rng = np.random.default_rng(21)
        n_bucket = 64
        t = CFG.tsp_layer
        kv, hd, w = CFG.n_kv_heads, CFG.head_dim, CFG.window
        for n_valid, chunk in [(64, 16), (64, 24), (50, 16), (33, 64)]:
            toks = np.zeros(n_bucket, np.int32)
            toks[:n_valid] = np.asarray(_toks(rng, n_valid))
            toks_j = jnp.asarray(toks)
            hid, k1, v1, w1, _ = M.prefill_stage1(
                flat, toks_j, jnp.int32(n_valid), cfg=CFG
            )
            kbuf = np.zeros((t, n_bucket, kv, hd), np.float32)
            vbuf = np.zeros_like(kbuf)
            hbuf = np.zeros((n_bucket, CFG.d_model), np.float32)
            win_last = None
            for start, ln in spans(n_valid, chunk, w):
                ctoks = np.zeros(chunk, np.int32)
                ctoks[:ln] = toks[start:start + ln]
                ch, kc, vc, cw, _ = M.prefill_stage1_chunk(
                    flat, jnp.asarray(ctoks), jnp.asarray(kbuf),
                    jnp.asarray(vbuf), jnp.int32(start), jnp.int32(ln),
                    jnp.int32(n_valid), cfg=CFG
                )
                hbuf[start:start + ln] = np.asarray(ch)[:ln]
                kbuf[:, start:start + ln] = np.asarray(kc)[:, :ln]
                vbuf[:, start:start + ln] = np.asarray(vc)[:, :ln]
                win_last = np.asarray(cw)
            msg = f"n_valid={n_valid} chunk={chunk}"
            np.testing.assert_array_equal(
                np.asarray(hid)[:n_valid], hbuf[:n_valid], err_msg=msg
            )
            np.testing.assert_array_equal(
                np.asarray(k1)[:, :n_valid], kbuf[:, :n_valid], err_msg=msg
            )
            np.testing.assert_array_equal(
                np.asarray(v1)[:, :n_valid], vbuf[:, :n_valid], err_msg=msg
            )
            np.testing.assert_array_equal(
                np.asarray(w1), win_last, err_msg=msg
            )

    def test_padding_invariance(self, flat):
        """A prompt padded into a larger bucket produces the same logits."""
        rng = np.random.default_rng(3)
        toks = _toks(rng, 48)
        lg1, *_ = M.prefill_full(
            flat, jnp.pad(toks, (0, 16)), jnp.int32(48), cfg=CFG
        )
        lg2, *_ = M.prefill_full(
            flat, jnp.pad(toks, (0, 80)), jnp.int32(48), cfg=CFG
        )
        np.testing.assert_allclose(lg1, lg2, rtol=1e-4, atol=1e-4)

    def test_sweep_tsp_full_rate_matches_full(self, flat):
        """TSP that keeps every token must not change the output."""
        rng = np.random.default_rng(4)
        n = 64
        toks = _toks(rng, n)
        lg, *_ , fh = M.prefill_full(flat, toks, jnp.int32(n), cfg=CFG)
        lg2, fh2 = M.sweep_tsp(flat, toks, jnp.int32(n), cfg=CFG, t=2, nt=n)
        np.testing.assert_allclose(lg, lg2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(fh, fh2, rtol=1e-4, atol=1e-4)

    def test_sweep_later_layer_closer_to_full(self, flat):
        """Fig. 3 property: the hidden-state L2 distance to the full
        baseline shrinks (weakly) as the TSP layer moves later."""
        rng = np.random.default_rng(5)
        n = 64
        toks = _toks(rng, n)
        _, fh = M.prefill_full(flat, toks, jnp.int32(n), cfg=CFG)[0], \
            M.prefill_full(flat, toks, jnp.int32(n), cfg=CFG)[5]
        dists = []
        for t in range(1, CFG.n_layers):
            _, fht = M.sweep_tsp(flat, toks, jnp.int32(n), cfg=CFG, t=t,
                                 nt=16)
            dists.append(float(jnp.linalg.norm(fht - fh)))
        assert dists[-1] <= dists[0]


class TestDecode:
    def test_decode_matches_extended_prefill(self, flat):
        """Greedy-decoding one token over the full uncompressed cache must
        equal re-running prefill over the extended sequence."""
        rng = np.random.default_rng(6)
        n, c = 48, 96
        toks = _toks(rng, n)
        lg, k, v, *_ = M.prefill_full(
            flat, jnp.pad(toks, (0, 16)), jnp.int32(n), cfg=CFG
        )
        lcfg = CFG
        kc = np.zeros((lcfg.n_layers, 1, c, lcfg.n_kv_heads,
                       lcfg.head_dim), np.float32)
        vc = np.zeros_like(kc)
        kc[:, 0, :64] = np.asarray(k)
        vc[:, 0, :64] = np.asarray(v)
        # zero out padded rows (they were masked in attention anyway)
        kc[:, 0, n:64] = 0
        vc[:, 0, n:64] = 0
        nxt = jnp.argmax(lg).astype(jnp.int32)
        lgd, kn, vn = M.decode_step(
            flat, nxt[None], jnp.asarray([n], jnp.int32),
            jnp.asarray(kc), jnp.asarray(vc),
            jnp.full((lcfg.n_layers, 1), n, jnp.int32), cfg=CFG,
        )
        ext = jnp.concatenate([toks, nxt[None]])
        lgf, *_ = M.prefill_full(
            flat, jnp.pad(ext, (0, 15)), jnp.int32(n + 1), cfg=CFG
        )
        np.testing.assert_allclose(
            np.asarray(lgd[0]), np.asarray(lgf), rtol=1e-3, atol=1e-3
        )

    def test_decode_batch_consistency(self, flat):
        """A batch-4 decode must equal four independent batch-1 decodes."""
        rng = np.random.default_rng(7)
        lcfg = CFG
        c = 96
        kc = rng.normal(size=(lcfg.n_layers, 4, c, lcfg.n_kv_heads,
                              lcfg.head_dim)).astype(np.float32) * 0.3
        vc = rng.normal(size=kc.shape).astype(np.float32) * 0.3
        lens = np.asarray([[10, 20, 30, 40]] * lcfg.n_layers, np.int32)
        toks = jnp.asarray([5, 9, 70, 100], jnp.int32)
        poss = jnp.asarray([10, 20, 30, 40], jnp.int32)
        lg_b, kn_b, vn_b = M.decode_step(
            flat, toks, poss, jnp.asarray(kc), jnp.asarray(vc),
            jnp.asarray(lens), cfg=CFG,
        )
        for i in range(4):
            lg_1, kn_1, vn_1 = M.decode_step(
                flat, toks[i : i + 1], poss[i : i + 1],
                jnp.asarray(kc[:, i : i + 1]), jnp.asarray(vc[:, i : i + 1]),
                jnp.asarray(lens[:, i : i + 1]), cfg=CFG,
            )
            np.testing.assert_allclose(
                np.asarray(lg_b[i]), np.asarray(lg_1[0]), rtol=1e-4,
                atol=1e-4,
            )

    def test_paged_decode_equals_dense_decode(self, flat):
        """Block-table decode over a scattered slab must equal dense decode
        over the same logical caches (logits and new KV rows), including
        lanes with per-layer lens, shared blocks, and partial tails."""
        rng = np.random.default_rng(9)
        lcfg = CFG
        b, bt, mb = 2, 4, 6
        c = bt * mb  # 24: dense capacity == gathered capacity
        nb = 40      # slab bigger than needed; unused blocks hold junk
        lens = np.asarray(
            [[5, 11], [8, 3], [23, 16], [1, 20]][: lcfg.n_layers], np.int32
        )
        kc = np.zeros((lcfg.n_layers, b, c, lcfg.n_kv_heads,
                       lcfg.head_dim), np.float32)
        vc = np.zeros_like(kc)
        slab_k = rng.normal(size=(nb, bt, lcfg.n_kv_heads,
                                  lcfg.head_dim)).astype(np.float32)
        slab_v = rng.normal(size=slab_k.shape).astype(np.float32) * 0.5
        tables = np.full((lcfg.n_layers, b, mb), -1, np.int32)
        # Scatter each lane's cache into randomly-chosen slab blocks and
        # mirror the gathered content into the dense layout.
        free = list(rng.permutation(nb - 1) + 1)  # block 0 left as junk
        for l in range(lcfg.n_layers):
            for s in range(b):
                n = int(lens[l, s])
                nblk = -(-n // bt)
                for i in range(nblk):
                    blk = int(free.pop())
                    tables[l, s, i] = blk
                    rows = min(bt, n - i * bt)
                    kc[l, s, i * bt : i * bt + rows] = slab_k[blk, :rows]
                    vc[l, s, i * bt : i * bt + rows] = slab_v[blk, :rows]
        toks = jnp.asarray([5, 97], jnp.int32)
        poss = jnp.asarray([30, 41], jnp.int32)
        lg_d, kn_d, vn_d = M.decode_step(
            flat, toks, poss, jnp.asarray(kc), jnp.asarray(vc),
            jnp.asarray(lens), cfg=CFG,
        )
        lg_p, kn_p, vn_p = M.decode_paged_step(
            flat, toks, poss, jnp.asarray(slab_k), jnp.asarray(slab_v),
            jnp.asarray(tables), jnp.asarray(lens), cfg=CFG,
        )
        np.testing.assert_allclose(
            np.asarray(lg_p), np.asarray(lg_d), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(kn_p), np.asarray(kn_d), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(vn_p), np.asarray(vn_d), rtol=1e-4, atol=1e-4
        )

    def test_paged_decode_ignores_rows_past_lens(self, flat):
        """Rows beyond lens — junk in partially-filled tail blocks or
        whole stale blocks reachable through clipped -1 entries — must not
        influence the outputs."""
        rng = np.random.default_rng(10)
        lcfg = CFG
        b, bt, mb = 1, 4, 3
        nb = 2 * lcfg.n_layers + 1
        lens = np.full((lcfg.n_layers, b), 6, np.int32)  # 1.5 blocks
        slab_k = rng.normal(size=(nb, bt, lcfg.n_kv_heads,
                                  lcfg.head_dim)).astype(np.float32)
        slab_v = rng.normal(size=slab_k.shape).astype(np.float32)
        tables = np.full((lcfg.n_layers, b, mb), -1, np.int32)
        for l in range(lcfg.n_layers):
            tables[l, 0, 0] = 2 * l + 1
            tables[l, 0, 1] = 2 * l + 2
        toks = jnp.asarray([17], jnp.int32)
        poss = jnp.asarray([6], jnp.int32)
        out1 = M.decode_paged_step(
            flat, toks, poss, jnp.asarray(slab_k), jnp.asarray(slab_v),
            jnp.asarray(tables), jnp.asarray(lens), cfg=CFG,
        )
        # poison every row past lens in referenced tail blocks + block 0
        slab_k2, slab_v2 = slab_k.copy(), slab_v.copy()
        for l in range(lcfg.n_layers):
            slab_k2[2 * l + 2, 2:] = 1e3   # rows 2,3 of the tail block
            slab_v2[2 * l + 2, 2:] = -1e3
        slab_k2[0] = 7e2
        slab_v2[0] = -7e2
        out2 = M.decode_paged_step(
            flat, toks, poss, jnp.asarray(slab_k2), jnp.asarray(slab_v2),
            jnp.asarray(tables), jnp.asarray(lens), cfg=CFG,
        )
        for a, b_ in zip(out1, out2):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b_), rtol=1e-5, atol=1e-5
            )

    def test_sharded_paged_decode_equals_paged_decode(self):
        """KV-head-sharded decode (S slab pairs concatenated in HLO, plus
        the host-side head-shard recombination of the outputs) must equal
        the unsharded paged decode bit-for-bit up to float tolerance —
        logits AND the reassembled k_new/v_new."""
        # TEST has a single KV head; sharding needs a divisible count.
        scfg = ModelConfig(
            d_model=32, n_layers=2, n_heads=4, n_kv_heads=2, d_ffn=64,
            tsp_layer=1, max_train_len=128,
        )
        sflat = jnp.asarray(flatten(init_params(scfg, 5), scfg))
        rng = np.random.default_rng(11)
        b, bt, mb, shards = 2, 4, 4, 2
        nb = scfg.n_layers * b * mb
        kvs = scfg.n_kv_heads // shards
        slab_k = rng.normal(size=(nb, bt, scfg.n_kv_heads,
                                  scfg.head_dim)).astype(np.float32)
        slab_v = rng.normal(size=slab_k.shape).astype(np.float32) * 0.5
        lens = np.asarray([[5, 9], [12, 3]][: scfg.n_layers], np.int32)
        tables = np.full((scfg.n_layers, b, mb), -1, np.int32)
        free = list(rng.permutation(nb))
        for l in range(scfg.n_layers):
            for s in range(b):
                for i in range(-(-int(lens[l, s]) // bt)):
                    tables[l, s, i] = int(free.pop())
        toks = jnp.asarray([5, 97], jnp.int32)
        poss = jnp.asarray([int(lens[:, 0].max()),
                            int(lens[:, 1].max())], jnp.int32)
        lg_p, kn_p, vn_p = M.decode_paged_step(
            sflat, toks, poss, jnp.asarray(slab_k), jnp.asarray(slab_v),
            jnp.asarray(tables), jnp.asarray(lens), cfg=scfg,
        )
        # shard the slab head-wise and run the sharded entry point
        shard_slabs = []
        for s in range(shards):
            shard_slabs.append(
                jnp.asarray(slab_k[:, :, s * kvs:(s + 1) * kvs, :]))
            shard_slabs.append(
                jnp.asarray(slab_v[:, :, s * kvs:(s + 1) * kvs, :]))
        out = M.decode_paged_shard_step(
            sflat, toks, poss, *shard_slabs,
            jnp.asarray(tables), jnp.asarray(lens), cfg=scfg, shards=shards,
        )
        assert len(out) == 1 + 2 * shards
        lg_s = out[0]
        # host-side combine: concatenate shard slices along the KV axis
        kn_s = jnp.concatenate(out[1::2], axis=2)
        vn_s = jnp.concatenate(out[2::2], axis=2)
        np.testing.assert_allclose(
            np.asarray(lg_s), np.asarray(lg_p), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(kn_s), np.asarray(kn_p), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(vn_s), np.asarray(vn_p), rtol=1e-5, atol=1e-5
        )
        # per-shard outputs really are head slices (exact equality)
        kvs_slice = np.asarray(out[1])
        np.testing.assert_array_equal(
            kvs_slice, np.asarray(kn_p)[:, :, :kvs, :]
        )

    @staticmethod
    def _quantize_slab(slab):
        """Per-row int8 quantization matching rust ``paging::codec``:
        ``scale = max|row| / 127``, ``q = round(x / scale)`` clipped to
        [-127, 127]; zero rows carry scale 0. Codes return as
        integer-valued f32 (the runtime ABI is f32-only)."""
        nb, bt = slab.shape[:2]
        rows = slab.reshape(nb, bt, -1)
        scales = (np.abs(rows).max(axis=2) / 127.0).astype(np.float32)
        safe = np.maximum(scales[:, :, None], np.float32(1e-30))
        q = np.where(
            scales[:, :, None] > 0,
            np.clip(np.round(rows / safe), -127, 127),
            np.float32(0),
        ).astype(np.float32)
        return q.reshape(slab.shape), scales

    def test_q8_paged_decode_equals_dequant_then_paged(self, flat):
        """The q8 artifact's in-HLO dequant must equal host-side dequant
        followed by the plain paged decode — both compute the same
        ``q * scale`` product in f32, so tolerances are tight. This is
        the contract that lets the rust planner treat the q8 path and
        the host-dequant fallback as interchangeable."""
        rng = np.random.default_rng(12)
        lcfg = CFG
        b, bt, mb = 2, 4, 3
        nb = lcfg.n_layers * b * mb
        slab_k = rng.normal(size=(nb, bt, lcfg.n_kv_heads,
                                  lcfg.head_dim)).astype(np.float32)
        slab_v = rng.normal(size=slab_k.shape).astype(np.float32) * 0.5
        kq, ksc = self._quantize_slab(slab_k)
        vq, vsc = self._quantize_slab(slab_v)
        lens = np.asarray(
            [[5, 11], [8, 3], [12, 7], [1, 9]][: lcfg.n_layers], np.int32
        )
        tables = np.full((lcfg.n_layers, b, mb), -1, np.int32)
        free = list(rng.permutation(nb))
        for l in range(lcfg.n_layers):
            for s in range(b):
                for i in range(-(-int(lens[l, s]) // bt)):
                    tables[l, s, i] = int(free.pop())
        toks = jnp.asarray([5, 97], jnp.int32)
        poss = jnp.asarray(
            [int(lens[:, s].max()) for s in range(b)], jnp.int32
        )
        deq_k = kq * ksc[:, :, None, None]
        deq_v = vq * vsc[:, :, None, None]
        ref = M.decode_paged_step(
            flat, toks, poss, jnp.asarray(deq_k), jnp.asarray(deq_v),
            jnp.asarray(tables), jnp.asarray(lens), cfg=CFG,
        )
        out = M.decode_paged_q8_step(
            flat, toks, poss, jnp.asarray(kq), jnp.asarray(ksc),
            jnp.asarray(vq), jnp.asarray(vsc),
            jnp.asarray(tables), jnp.asarray(lens), cfg=CFG,
        )
        for got, want in zip(out, ref):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6
            )
        # And the quantization itself is a faithful approximation: the
        # dequantized slab is within scale/2 of the source per element.
        bound = np.maximum(ksc[:, :, None], 0)[..., None] / 2 + 1e-7
        assert (np.abs(deq_k - slab_k) <= bound).all()

    def test_q8_sharded_decode_equals_q8_unsharded(self):
        """Sharded q8 (per-shard quant planes, full-row scales shared by
        every shard of a row) must equal the unsharded q8 decode."""
        scfg = ModelConfig(
            d_model=32, n_layers=2, n_heads=4, n_kv_heads=2, d_ffn=64,
            tsp_layer=1, max_train_len=128,
        )
        sflat = jnp.asarray(flatten(init_params(scfg, 6), scfg))
        rng = np.random.default_rng(13)
        b, bt, mb, shards = 2, 4, 4, 2
        nb = scfg.n_layers * b * mb
        kvs = scfg.n_kv_heads // shards
        slab_k = rng.normal(size=(nb, bt, scfg.n_kv_heads,
                                  scfg.head_dim)).astype(np.float32)
        slab_v = rng.normal(size=slab_k.shape).astype(np.float32) * 0.5
        kq, ksc = self._quantize_slab(slab_k)
        vq, vsc = self._quantize_slab(slab_v)
        lens = np.asarray([[5, 9], [12, 3]][: scfg.n_layers], np.int32)
        tables = np.full((scfg.n_layers, b, mb), -1, np.int32)
        free = list(rng.permutation(nb))
        for l in range(scfg.n_layers):
            for s in range(b):
                for i in range(-(-int(lens[l, s]) // bt)):
                    tables[l, s, i] = int(free.pop())
        toks = jnp.asarray([5, 97], jnp.int32)
        poss = jnp.asarray(
            [int(lens[:, s].max()) for s in range(b)], jnp.int32
        )
        lg_q, kn_q, vn_q = M.decode_paged_q8_step(
            sflat, toks, poss, jnp.asarray(kq), jnp.asarray(ksc),
            jnp.asarray(vq), jnp.asarray(vsc),
            jnp.asarray(tables), jnp.asarray(lens), cfg=scfg,
        )
        shard_ins = []
        for s in range(shards):
            shard_ins += [
                jnp.asarray(kq[:, :, s * kvs:(s + 1) * kvs, :]),
                jnp.asarray(ksc),
                jnp.asarray(vq[:, :, s * kvs:(s + 1) * kvs, :]),
                jnp.asarray(vsc),
            ]
        out = M.decode_paged_q8_shard_step(
            sflat, toks, poss, *shard_ins,
            jnp.asarray(tables), jnp.asarray(lens),
            cfg=scfg, shards=shards,
        )
        assert len(out) == 1 + 2 * shards
        kn_s = jnp.concatenate(out[1::2], axis=2)
        vn_s = jnp.concatenate(out[2::2], axis=2)
        np.testing.assert_allclose(
            np.asarray(out[0]), np.asarray(lg_q), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(kn_s), np.asarray(kn_q), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(vn_s), np.asarray(vn_q), rtol=1e-5, atol=1e-5
        )

    def test_compressed_cache_changes_little_when_keeping_salient(
        self, flat
    ):
        """Dropping the *lowest*-scoring half of the cache perturbs decode
        logits less than dropping the highest-scoring half."""
        rng = np.random.default_rng(8)
        n = 64
        toks = _toks(rng, n)
        lg, k, v, win, acc, _ = M.prefill_full(
            flat, toks, jnp.int32(n), cfg=CFG
        )
        score = np.asarray(win).mean(axis=1)          # [L, N]
        lcfg = CFG
        c = 96
        keep = n // 2

        def decode_with(sel_per_layer):
            kc = np.zeros((lcfg.n_layers, 1, c, lcfg.n_kv_heads,
                           lcfg.head_dim), np.float32)
            vc = np.zeros_like(kc)
            lens = np.zeros((lcfg.n_layers, 1), np.int32)
            for l in range(lcfg.n_layers):
                sel = np.sort(sel_per_layer[l])
                kc[l, 0, : len(sel)] = np.asarray(k)[l, sel]
                vc[l, 0, : len(sel)] = np.asarray(v)[l, sel]
                lens[l, 0] = len(sel)
            nxt = jnp.argmax(lg).astype(jnp.int32)
            lgd, *_ = M.decode_step(
                flat, nxt[None], jnp.asarray([n], jnp.int32),
                jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(lens),
                cfg=CFG,
            )
            return np.asarray(lgd[0])

        top = [np.argsort(-score[l])[:keep] for l in range(lcfg.n_layers)]
        bot = [np.argsort(score[l])[:keep] for l in range(lcfg.n_layers)]
        full_sel = [np.arange(n)] * lcfg.n_layers
        ref_lg = decode_with(full_sel)
        d_top = np.linalg.norm(decode_with(top) - ref_lg)
        d_bot = np.linalg.norm(decode_with(bot) - ref_lg)
        assert d_top < d_bot


class TestPyramid:
    def test_schedule_monotone(self):
        sched = M.pyramid_schedule(CFG, 256)
        assert sched[0] == 256
        assert all(a >= b for a, b in zip(sched, sched[1:]))
        assert sched[-1] >= int(256 * 0.6)

    def test_pyramid_lens_match_schedule(self, flat):
        rng = np.random.default_rng(9)
        n = 64
        toks = _toks(rng, n)
        _, kp, vp, lens = M.prefill_pyramid(flat, toks, jnp.int32(n),
                                            cfg=CFG)
        sched = M.pyramid_schedule(CFG, n)
        np.testing.assert_array_equal(np.asarray(lens), sched)

    def test_pyramid_layer0_matches_full(self, flat):
        """Layer 0 processes the full context, so its KV equals full's."""
        rng = np.random.default_rng(10)
        n = 64
        toks = _toks(rng, n)
        _, k, *_ = M.prefill_full(flat, toks, jnp.int32(n), cfg=CFG)
        _, kp, _, lens = M.prefill_pyramid(flat, toks, jnp.int32(n),
                                           cfg=CFG)
        np.testing.assert_allclose(
            np.asarray(k)[0], np.asarray(kp)[0], rtol=1e-4, atol=1e-4
        )


class TestTraining:
    def test_loss_decreases(self):
        from compile.train import make_step
        from compile import data

        rng = np.random.default_rng(0)
        small = ModelConfig(
            d_model=32, n_layers=2, n_heads=2, n_kv_heads=1, d_ffn=64,
            tsp_layer=1,
        )
        flat = jnp.asarray(flatten(init_params(small, 0), small))
        m = jnp.zeros_like(flat)
        v = jnp.zeros_like(flat)
        step = make_step(small, 2e-3, 30, 5)
        losses = []
        for t in range(1, 31):
            toks, mask = data.batch(rng, 4, 128)
            flat, m, v, loss = step(
                flat, m, v, jnp.float32(t), jnp.asarray(toks),
                jnp.asarray(mask),
            )
            losses.append(float(loss))
        assert np.mean(losses[-5:]) < np.mean(losses[:5])
