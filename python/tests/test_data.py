"""Synthetic corpus generators: wire-format invariants that the Rust
workload generators (rust/src/workload/) rely on."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data


ALL_GENS = list(data.GENERATORS.items())


@pytest.mark.parametrize("name,gen", ALL_GENS)
def test_shapes_and_mask(name, gen):
    rng = np.random.default_rng(0)
    for seed in range(5):
        t, m = gen(np.random.default_rng(seed), 256)
        assert t.shape == (256,)
        assert m.shape == (256,)
        assert m.sum() >= 1, name
        assert t.dtype == np.uint8 or t.max() < 256


@pytest.mark.parametrize("name,gen", ALL_GENS)
def test_answer_recoverable(name, gen):
    """The loss mask must point exactly at the answer bytes: the target of
    each masked position is the next byte, and the span ends with END."""
    for seed in range(10):
        t, m = gen(np.random.default_rng(seed), 256)
        idx = np.where(m > 0)[0]
        assert np.all(np.diff(idx) == 1), f"{name}: mask not contiguous"
        answer = t[idx + 1]
        assert answer[-1] == data.END, f"{name}: answer not END-terminated"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       seq_len=st.sampled_from([128, 256, 384]))
def test_kv_recall_needle_present(seed, seq_len):
    """The queried key must appear exactly twice (needle + query) and the
    value must follow the needle occurrence."""
    rng = np.random.default_rng(seed)
    t, m = data.gen_kv_recall(rng, seq_len)
    idx = np.where(m > 0)[0]
    value = bytes(t[idx + 1][:-1].astype(np.uint8))
    s = bytes(t.astype(np.uint8))
    q = s.rindex(bytes([data.QUERY, data.KEY_START]))
    key = s[q + 2 : s.index(bytes([data.KV_SEP]), q)]
    needle = bytes([data.KEY_START]) + key + bytes([data.KV_SEP]) + value
    assert needle in s[:q], "needle (key SEP value) must be in the context"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_count_marks_answer_matches(seed):
    rng = np.random.default_rng(seed)
    t, m = data.gen_count_marks(rng, 256)
    idx = np.where(m > 0)[0]
    digit = int(t[idx + 1][0]) - ord("0")
    n_marks = int(np.sum(t[: idx[0]] == data.MARK))
    assert digit == n_marks


def test_batch_shapes():
    rng = np.random.default_rng(0)
    toks, masks = data.batch(rng, 6, 256)
    assert toks.shape == (6, 256) and masks.shape == (6, 256)
    assert toks.dtype == np.int32
    assert np.all(toks >= 0) and np.all(toks < 256)


def test_mixture_covers_all_tasks():
    assert set(data.TRAIN_MIX) == set(data.GENERATORS)
    assert abs(sum(data.TRAIN_MIX.values()) - 1.0) < 1e-6
