"""tools/check.py: every named check must fire on injected drift and
stay silent on a clean fixture tree — plus the real repo passes clean.

Runs under pytest or plain `python3 python/tests/test_check.py`
(unittest), so the no-Rust CI lane needs nothing beyond the stdlib.
"""

import os
import shutil
import sys
import tempfile
import unittest

REPO_ROOT = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)
sys.path.insert(0, os.path.join(REPO_ROOT, "tools"))

import check  # noqa: E402


METRICS_RS = """\
pub mod names {
    /// Requests received by the serving thread.
    pub const SUBMITTED: &str = "submitted";
    /// Gauge name: blocks charged to the tenant.
    pub fn tenant_blocks_held(id: TenantId) -> String {
        format!("tenant_{id}_blocks_held")
    }
}

#[cfg(test)]
mod tests {
    fn raw_names_allowed_here() {
        m.inc("submitted");
    }
}
"""

SERVER_RS = """\
fn publish(m: &Metrics) {
    m.inc(names::SUBMITTED);
    m.set_gauge(&names::tenant_blocks_held(t), held);
}
"""

METRICS_MD = """\
# Metrics

| name | meaning |
|---|---|
| `submitted` | requests received |
| `tenant_{id}_blocks_held` | blocks charged to the tenant |
"""

MANIFEST_RS = """\
pub fn decode_paged_artifact_name(batch: usize, cap: usize) -> String {
    format!("decode_paged_{batch}x{cap}")
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let kind = a.req("kind");
        let blocks = a.get("pool_blocks");
    }
}
"""

AOT_PY = """\
def build(em, buckets):
    for b in buckets.decode_batches:
        for c in buckets.decode_caps:
            em.emit(f"decode_paged_{b}x{c}", fn, specs,
                    {"kind": "decode_paged", "pool_blocks": 64})
"""

MAIN_RS = """\
fn main() {
    let args = Args::from_env();
    let n = args.usize("requests", 16);
    let half = args.has("swap-half");
}
"""

CLI_RS = """\
impl Args {
    pub fn usize(&self, key: &str, default: usize) -> usize {
        default
    }
}

#[cfg(test)]
mod tests {
    fn flags_here_do_not_count() {
        let a = parse("--port 8080");
        a.get("port");
    }
}
"""

README_MD = """\
# Fixture

Serve with `--requests N`. Deprecated: `--swap-half` is a swap-only tier
(swapped lanes encode f16; the resident slab is untouched).
"""

TRACE_RS = """\
pub enum EventKind {
    /// Request entered the queue.
    Submit {
        prompt_tokens: u32,
    },
    /// Request failed permanently.
    Reject,
}

pub fn validate_lifecycle(events: &[Event]) -> Result<(), String> {
    use EventKind as K;
    match (state, ev.kind) {
        (S::Start, K::Submit { .. }) => S::Queued,
        (S::Queued, K::Reject) => S::Done,
    }
}
"""

EXPORT_RS = """\
fn chrome_trace(events: &[Event]) -> String {
    match ev.kind {
        EventKind::Submit { .. } => emit("submit"),
        EventKind::Reject => emit("reject"),
    }
}
"""

CARGO_TOML = """\
[package]
name = "fixture"

[[test]]
name = "integration"
path = "rust/tests/integration.rs"

[[bench]]
name = "paging"
path = "rust/benches/paging.rs"

[dependencies]
anyhow = { path = "rust/vendor/anyhow" }
"""


class FixtureTree:
    """A throwaway mini-repo; write(rel, text) then run checks on it."""

    def __init__(self):
        self.root = tempfile.mkdtemp(prefix="fastkv-check-")

    def write(self, rel, text):
        path = os.path.join(self.root, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            f.write(text)

    def remove(self, rel):
        os.remove(os.path.join(self.root, rel))

    def destroy(self):
        shutil.rmtree(self.root, ignore_errors=True)


class CheckTestCase(unittest.TestCase):
    def setUp(self):
        self.tree = FixtureTree()
        self.addCleanup(self.tree.destroy)

    def run_check(self, name):
        return check.run(self.tree.root, only={name})

    def assert_fires(self, name, needle):
        findings = self.run_check(name)
        self.assertTrue(
            any(needle in f for f in findings),
            f"expected a `{name}` finding mentioning `{needle}`, "
            f"got: {findings}",
        )

    def assert_clean(self, name):
        self.assertEqual(self.run_check(name), [])


class TestMetrics(CheckTestCase):
    def setUp(self):
        super().setUp()
        self.tree.write("rust/src/metrics.rs", METRICS_RS)
        self.tree.write("rust/src/coordinator/server.rs", SERVER_RS)
        self.tree.write("docs/metrics.md", METRICS_MD)

    def test_clean_fixture_passes(self):
        self.assert_clean("metrics")

    def test_undocumented_metric_fires(self):
        self.tree.write(
            "rust/src/metrics.rs",
            METRICS_RS.replace(
                'pub const SUBMITTED: &str = "submitted";',
                'pub const SUBMITTED: &str = "submitted";\n'
                '    /// Requests retired.\n'
                '    pub const COMPLETED: &str = "completed";',
            ),
        )
        self.assert_fires("metrics", "`completed` (COMPLETED) has no row")

    def test_orphaned_doc_row_fires(self):
        self.tree.write(
            "docs/metrics.md", METRICS_MD + "| `ghost_metric` | gone |\n"
        )
        self.assert_fires("metrics", "`ghost_metric`")

    def test_tenant_placeholder_regression(self):
        # the exact tenant_{t} vs tenant_{id} drift this tooling was
        # built to catch: same normalized name, different spelling
        self.tree.write(
            "rust/src/metrics.rs",
            METRICS_RS.replace("id: TenantId", "t: TenantId").replace(
                "tenant_{id}_blocks_held", "tenant_{t}_blocks_held"
            ),
        )
        self.assert_fires("metrics", "placeholder `{t}` vs `{id}`")

    def test_unpublished_metric_fires(self):
        self.tree.write(
            "rust/src/coordinator/server.rs",
            SERVER_RS.replace("m.inc(names::SUBMITTED);", ""),
        )
        self.assert_fires("metrics", "no publish site")


class TestArtifacts(CheckTestCase):
    def setUp(self):
        super().setUp()
        self.tree.write("rust/src/manifest.rs", MANIFEST_RS)
        self.tree.write("python/compile/aot.py", AOT_PY)

    def test_clean_fixture_passes(self):
        self.assert_clean("artifacts")

    def test_renamed_artifact_bucket_fires(self):
        # python renames the family; rust still resolves the old name
        self.tree.write(
            "python/compile/aot.py",
            AOT_PY.replace("decode_paged_{b}x{c}", "decode_blktab_{b}x{c}"),
        )
        self.assert_fires("artifacts", "decode_paged_{batch}x{cap}")

    def test_unemitted_manifest_key_fires(self):
        self.tree.write(
            "rust/src/manifest.rs",
            MANIFEST_RS.replace(
                'a.get("pool_blocks")', 'a.get("pool_pages")'
            ),
        )
        self.assert_fires("artifacts", "`pool_pages`")


class TestCli(CheckTestCase):
    def setUp(self):
        super().setUp()
        self.tree.write("rust/src/main.rs", MAIN_RS)
        self.tree.write("rust/src/util/cli.rs", CLI_RS)
        self.tree.write("README.md", README_MD)

    def test_clean_fixture_passes(self):
        self.assert_clean("cli")

    def test_undocumented_flag_fires(self):
        self.tree.write(
            "rust/src/main.rs",
            MAIN_RS + 'fn extra(args: &Args) { args.has("turbo"); }\n',
        )
        self.assert_fires("cli", "`--turbo`")

    def test_cfg_test_flags_ignored(self):
        # cli.rs parses "port" only inside #[cfg(test)]: not a real flag
        self.assert_clean("cli")

    def test_pinned_deprecated_wording(self):
        self.tree.write(
            "README.md",
            README_MD.replace("swap-only tier", "half-precision swap"),
        )
        self.assert_fires("cli", "pinned wording")


class TestLifecycle(CheckTestCase):
    def setUp(self):
        super().setUp()
        self.tree.write("rust/src/obs/trace.rs", TRACE_RS)
        self.tree.write("rust/src/obs/export.rs", EXPORT_RS)

    def test_clean_fixture_passes(self):
        self.assert_clean("lifecycle")

    def test_unhandled_variant_fires_in_both_consumers(self):
        self.tree.write(
            "rust/src/obs/trace.rs",
            TRACE_RS.replace(
                "    /// Request failed permanently.",
                "    /// Compaction fired.\n"
                "    Compact,\n"
                "    /// Request failed permanently.",
            ),
        )
        findings = self.run_check("lifecycle")
        self.assertTrue(
            any("Compact" in f and "validate_lifecycle" in f for f in findings),
            findings,
        )
        self.assertTrue(
            any("Compact" in f and "Chrome-trace" in f for f in findings),
            findings,
        )


class TestCargo(CheckTestCase):
    def setUp(self):
        super().setUp()
        self.tree.write("Cargo.toml", CARGO_TOML)
        self.tree.write("rust/tests/integration.rs", "fn t() {}\n")
        self.tree.write("rust/benches/paging.rs", "fn b() {}\n")

    def test_clean_fixture_passes(self):
        self.assert_clean("cargo")

    def test_stale_test_entry_fires(self):
        self.tree.remove("rust/tests/integration.rs")
        self.assert_fires("cargo", "missing file rust/tests/integration.rs")

    def test_unregistered_test_file_fires(self):
        self.tree.write("rust/tests/orphan.rs", "fn t() {}\n")
        self.assert_fires("cargo", "rust/tests/orphan.rs")

    def test_path_included_helper_exempt(self):
        # bench_util.rs-style helper modules are not cargo targets
        self.tree.write("rust/benches/bench_util.rs", "pub fn h() {}\n")
        self.tree.write(
            "rust/benches/paging.rs",
            '#[path = "bench_util.rs"]\nmod bench_util;\nfn b() {}\n',
        )
        self.assert_clean("cargo")

    def test_registry_dependency_fires(self):
        self.tree.write(
            "Cargo.toml", CARGO_TOML + 'serde = "1.0"\n'
        )
        self.assert_fires("cargo", "`serde`")


CI_YML = """\
jobs:
  rust:
    steps:
      - name: Bench summary
        run: cat BENCH_fixture.json
      - name: Upload bench summary
        uses: actions/upload-artifact@v4
        with:
          name: BENCH_fixture
          path: BENCH_fixture.json
"""

BENCH_RS = """\
//! Doc-comment mention of BENCH_ghost.json must not count as produced.
fn main() {
    std::fs::write("BENCH_fixture.json", &json)
        .expect("write BENCH_fixture.json");
}
"""


class TestBenchArtifacts(CheckTestCase):
    def setUp(self):
        super().setUp()
        self.tree.write(".github/workflows/ci.yml", CI_YML)
        self.tree.write("rust/benches/paging.rs", BENCH_RS)

    def test_clean_fixture_passes(self):
        self.assert_clean("bench_artifacts")

    def test_ci_consuming_unwritten_artifact_fires(self):
        # the bench renames its output; CI still cats the old name
        self.tree.write(
            "rust/benches/paging.rs",
            BENCH_RS.replace("BENCH_fixture.json", "BENCH_renamed.json"),
        )
        self.assert_fires("bench_artifacts", "`BENCH_fixture.json`")

    def test_unsurfaced_bench_artifact_fires(self):
        self.tree.write(
            "rust/benches/paging.rs",
            BENCH_RS + 'fn extra() { std::fs::write("BENCH_new.json", x); }\n',
        )
        self.assert_fires("bench_artifacts", "`BENCH_new.json`")

    def test_ondemand_src_emitter_exempt(self):
        # rust/src emitters (eval subcommand) are on-demand, not CI lanes
        self.tree.write(
            "rust/src/main.rs",
            'fn main() { std::fs::write("BENCH_eval.json", x); }\n',
        )
        self.assert_clean("bench_artifacts")


class TestLinks(CheckTestCase):
    def test_broken_relative_link_fires(self):
        self.tree.write("README.md", "see [missing](docs/nope.md)\n")
        self.assert_fires("links", "docs/nope.md")

    def test_resolving_links_pass(self):
        self.tree.write("docs/real.md", "# here\n")
        self.tree.write(
            "README.md", "see [real](docs/real.md) and [web](https://x.y)\n"
        )
        self.assert_clean("links")


class TestRealTree(unittest.TestCase):
    def test_real_repo_is_clean(self):
        findings = check.run(REPO_ROOT)
        self.assertEqual(findings, [], findings)


if __name__ == "__main__":
    unittest.main(verbosity=2)
