"""L1 correctness: Pallas fused attention kernel vs the pure-jnp oracle.

This is the core correctness signal for the kernel layer — the oracle in
kernels/ref.py defines the contract and hypothesis sweeps shapes, GQA
group counts, valid lengths and block sizes against it.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import attention_ref, maxpool1d_ref
from compile.kernels.attention import attention_pallas, vmem_bytes

RTOL = 2e-5
ATOL = 2e-5


def _rand(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _check(h, kv, n, hd, n_valid, window, block_q, seed=0):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (h, n, hd))
    k = _rand(rng, (kv, n, hd))
    v = _rand(rng, (kv, n, hd))
    nv = jnp.int32(n_valid)
    o1, w1, a1 = attention_ref(q, k, v, nv, window=window)
    o2, w2, a2 = attention_pallas(q, k, v, nv, window=window,
                                  block_q=block_q)
    for x, y, name in [(o1, o2, "o"), (w1, w2, "win"), (a1, a2, "acc")]:
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=RTOL, atol=ATOL,
            err_msg=f"{name} h={h} kv={kv} n={n} nv={n_valid} bq={block_q}",
        )


class TestKernelBasic:
    def test_full_length(self):
        _check(4, 2, 128, 24, 128, 8, 64)

    def test_padded(self):
        _check(4, 2, 128, 24, 100, 8, 64)

    def test_tiny_valid(self):
        _check(4, 2, 128, 24, 5, 8, 64)

    def test_valid_smaller_than_window(self):
        _check(4, 2, 64, 16, 3, 8, 32)

    def test_mha_no_gqa(self):
        _check(2, 2, 64, 16, 64, 8, 32)

    def test_mqa(self):
        _check(4, 1, 64, 16, 48, 8, 16)

    def test_block_equals_n(self):
        _check(2, 1, 64, 16, 64, 8, 64)

    def test_single_row_blocks(self):
        _check(2, 1, 32, 8, 20, 4, 1)


class TestKernelProperties:
    @settings(max_examples=25, deadline=None)
    @given(
        kv=st.integers(1, 3),
        groups=st.integers(1, 3),
        n_pow=st.integers(4, 7),
        hd=st.sampled_from([8, 16, 24]),
        frac=st.floats(0.05, 1.0),
        window=st.sampled_from([4, 8]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, kv, groups, n_pow, hd, frac, window, seed):
        n = 2 ** n_pow
        n_valid = max(1, int(n * frac))
        h = kv * groups
        block_q = min(32, n)
        _check(h, kv, n, hd, n_valid, window, block_q, seed)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), frac=st.floats(0.1, 1.0))
    def test_probability_mass_conserved(self, seed, frac):
        """Each valid query row distributes exactly 1.0 of attention mass,
        so sum(acc) == number of valid queries per head."""
        rng = np.random.default_rng(seed)
        h, kv, n, hd = 4, 2, 64, 16
        n_valid = max(1, int(n * frac))
        q = _rand(rng, (h, n, hd))
        k = _rand(rng, (kv, n, hd))
        v = _rand(rng, (kv, n, hd))
        _, win, acc = attention_pallas(q, k, v, jnp.int32(n_valid),
                                       window=8, block_q=32)
        np.testing.assert_allclose(
            np.asarray(acc).sum(axis=-1), np.full(h, n_valid),
            rtol=1e-4,
        )
        np.testing.assert_allclose(
            np.asarray(win).sum(axis=-1), np.full(h, min(8, n_valid)),
            rtol=1e-4,
        )

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_win_le_acc(self, seed):
        """Window mass is a subset of total mass."""
        rng = np.random.default_rng(seed)
        q = _rand(rng, (2, 32, 8))
        k = _rand(rng, (1, 32, 8))
        v = _rand(rng, (1, 32, 8))
        _, win, acc = attention_pallas(q, k, v, jnp.int32(32), window=8,
                                       block_q=16)
        assert np.all(np.asarray(win) <= np.asarray(acc) + 1e-6)


class TestMaxpool:
    def test_basic(self):
        x = jnp.asarray([0.0, 5.0, 0.0, 0.0, 0.0, 0.0, 1.0])
        out = np.asarray(maxpool1d_ref(x, 3))
        np.testing.assert_allclose(out, [5, 5, 5, 0, 0, 1, 1])

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(8, 64), kernel=st.sampled_from([3, 5, 7]),
           seed=st.integers(0, 1000))
    def test_against_naive(self, n, kernel, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=n).astype(np.float32)
        out = np.asarray(maxpool1d_ref(jnp.asarray(x), kernel))
        pad = kernel // 2
        for i in range(n):
            lo, hi = max(0, i - pad), min(n, i + pad + 1)
            assert out[i] == pytest.approx(x[lo:hi].max())


def test_vmem_estimate_within_budget():
    """§Perf guard: the largest bucket's kernel instance must fit VMEM."""
    assert vmem_bytes(n=2048, hd=24, block_q=64) < 16 * 1024 * 1024
