"""Synthetic long-context task corpus (byte-level).

This substitutes the paper's pretrained-LLM capabilities (see DESIGN.md):
the tiny model is *trained* on retrieval-style tasks so that KV compression
policies have real accuracy consequences (drop the needle's KV entries and
the model demonstrably fails).

Byte-format spec — the Rust workload generators (rust/src/workload/) emit
the SAME wire format; keep the two in sync:

  0x01 KEY_START   begins a key span
  0x02 KV_SEP      separates key from value
  0x03 END         terminates a value / answer
  0x04 QUERY       begins the final query
  0x05 MARK        marks a topic word (aggregation tasks)
  0x06 DOC_SEP     document boundary
  filler           lowercase letters + space
  keys/values      3-6 lowercase letters

Tasks (LongBench/RULER analog mapping in DESIGN.md):
  kv_recall    single needle:  ... \x01 k \x02 v \x03 ...  \x04\x01 k \x02 -> v\x03
  kv_multi     m needles, query one (multi-key NIAH)
  hop2         k1 -> k2, k2 -> v; query k1 -> v (multi-hop / VT)
  marked_copy  emit the marked words in order (CWE / summarization analog)
  echo_upper   few-shot: word -> UPPERCASE word, demonstrated then queried
  count_marks  emit the count (single digit) of \x05 marks
"""

import numpy as np

KEY_START, KV_SEP, END, QUERY, MARK, DOC_SEP = 1, 2, 3, 4, 5, 6
LOWER = np.arange(ord("a"), ord("z") + 1)
SPACE = ord(" ")

TASKS = ("kv_recall", "kv_multi", "hop2", "marked_copy", "echo_upper",
         "count_marks")

# Default training mixture (weights sum to 1).
TRAIN_MIX = {
    "kv_recall": 0.32,
    "kv_multi": 0.22,
    "hop2": 0.14,
    "marked_copy": 0.12,
    "echo_upper": 0.12,
    "count_marks": 0.08,
}


def _word(rng, lo=3, hi=6):
    n = int(rng.integers(lo, hi + 1))
    return rng.choice(LOWER, n).astype(np.uint8)


def _filler(rng, n):
    """Lowercase words separated by spaces."""
    out = np.empty(n, np.uint8)
    i = 0
    while i < n:
        w = _word(rng, 2, 7)
        take = min(len(w), n - i)
        out[i : i + take] = w[:take]
        i += take
        if i < n:
            out[i] = SPACE
            i += 1
    return out


def _pair(k, v):
    return np.concatenate(
        [[KEY_START], k, [KV_SEP], v, [END]]
    ).astype(np.uint8)


def _place(rng, body, inserts):
    """Scatter ``inserts`` (list of byte arrays) into ``body`` at random,
    non-overlapping, order-preserving offsets."""
    if not inserts:
        return body
    free = len(body)
    cuts = np.sort(rng.integers(0, free + 1, size=len(inserts)))
    parts, prev = [], 0
    for c, ins in zip(cuts, inserts):
        parts.append(body[prev:c])
        parts.append(ins)
        prev = c
    parts.append(body[prev:])
    return np.concatenate(parts)


def _finish(rng, ctx, query, answer, seq_len):
    """Assemble  [context][query] -> answer\x03  padded/truncated to
    seq_len; returns (tokens [seq_len], loss_mask [seq_len]) where the mask
    covers the answer bytes (next-token targets)."""
    answer = np.concatenate([answer, [END]]).astype(np.uint8)
    tail = np.concatenate([query, answer])
    room = seq_len - len(tail)
    assert room > 8, "seq_len too small for task"
    ctx = ctx[:room] if len(ctx) >= room else np.concatenate(
        [ctx, _filler(rng, room - len(ctx))]
    )
    seq = np.concatenate([ctx, tail])
    mask = np.zeros(seq_len, np.float32)
    ans_start = len(ctx) + len(query)
    # predict answer[j] from position ans_start+j-1
    mask[ans_start - 1 : ans_start - 1 + len(answer)] = 1.0
    return seq, mask


def gen_kv_recall(rng, seq_len, n_pairs=1, query_idx=None):
    keys = [_word(rng) for _ in range(n_pairs)]
    vals = [_word(rng) for _ in range(n_pairs)]
    qi = int(rng.integers(n_pairs)) if query_idx is None else query_idx
    tail_len = 2 + len(keys[qi]) + 1 + 7 + 2
    body = _filler(rng, seq_len - tail_len - sum(
        len(k) + len(v) + 3 for k, v in zip(keys, vals)
    ) - 4)
    ctx = _place(rng, body, [_pair(k, v) for k, v in zip(keys, vals)])
    query = np.concatenate([[QUERY, KEY_START], keys[qi], [KV_SEP]]).astype(
        np.uint8
    )
    return _finish(rng, ctx, query, vals[qi], seq_len)


def gen_kv_multi(rng, seq_len):
    return gen_kv_recall(rng, seq_len, n_pairs=int(rng.integers(2, 5)))


def gen_hop2(rng, seq_len):
    k1, k2, v = _word(rng), _word(rng), _word(rng)
    pairs = [_pair(k1, k2), _pair(k2, v)]
    if rng.random() < 0.5:
        pairs = pairs[::-1]
    body = _filler(rng, seq_len - 64)
    ctx = _place(rng, body, pairs)
    # two-hop query: \x04\x04 k1 \x02 -> v   (double QUERY marks the hop)
    query = np.concatenate([[QUERY, QUERY, KEY_START], k1, [KV_SEP]]).astype(
        np.uint8
    )
    return _finish(rng, ctx, query, v, seq_len)


def gen_marked_copy(rng, seq_len, n_marks=3):
    words = [_word(rng) for _ in range(n_marks)]
    inserts = [
        np.concatenate([[MARK], w, [END]]).astype(np.uint8) for w in words
    ]
    body = _filler(rng, seq_len - 64)
    ctx = _place(rng, body, inserts)
    query = np.array([QUERY, MARK], np.uint8)
    answer = np.concatenate(
        [b for w in words for b in (w, [SPACE])][:-1]
    ).astype(np.uint8)
    return _finish(rng, ctx, query, answer, seq_len)


def gen_echo_upper(rng, seq_len, shots=3):
    demo_words = [_word(rng) for _ in range(shots)]
    qword = _word(rng)
    demos = [
        np.concatenate([[KEY_START], w, [KV_SEP], w - 32, [END]]).astype(
            np.uint8
        )
        for w in demo_words
    ]
    body = _filler(rng, seq_len - 96)
    ctx = _place(rng, body, demos)
    query = np.concatenate([[QUERY, KEY_START], qword, [KV_SEP]]).astype(
        np.uint8
    )
    return _finish(rng, ctx, query, qword - 32, seq_len)


def gen_count_marks(rng, seq_len):
    n = int(rng.integers(1, 10))
    inserts = [
        np.concatenate([[MARK], _word(rng), [END]]).astype(np.uint8)
        for _ in range(n)
    ]
    body = _filler(rng, seq_len - 72)
    ctx = _place(rng, body, inserts)
    query = np.array([QUERY, QUERY, MARK], np.uint8)
    answer = np.array([ord("0") + n], np.uint8)
    return _finish(rng, ctx, query, answer, seq_len)


GENERATORS = {
    "kv_recall": gen_kv_recall,
    "kv_multi": gen_kv_multi,
    "hop2": gen_hop2,
    "marked_copy": gen_marked_copy,
    "echo_upper": gen_echo_upper,
    "count_marks": gen_count_marks,
}


def batch(rng, batch_size, seq_len, mix=TRAIN_MIX):
    """Returns (tokens [B, seq_len] i32, loss_mask [B, seq_len] f32)."""
    names = list(mix.keys())
    probs = np.array([mix[n] for n in names])
    probs = probs / probs.sum()
    toks = np.empty((batch_size, seq_len), np.int32)
    masks = np.empty((batch_size, seq_len), np.float32)
    for b in range(batch_size):
        name = names[int(rng.choice(len(names), p=probs))]
        t, m = GENERATORS[name](rng, seq_len)
        toks[b] = t.astype(np.int32)
        masks[b] = m
    return toks, masks
