"""L2 building blocks: RMSNorm, RoPE, GQA attention block, SwiGLU MLP.

Every block takes the per-layer parameter dict produced by
``params.unflatten`` and is pure jnp, so the whole decoder lowers to a
single HLO module.  The attention score path can run through either the
pure-jnp reference (default artifact path) or the L1 Pallas kernel
(``kernel="pallas"``) — both proven equivalent by the kernel test suite.
"""

import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.ref import (
    attention_ref,
    chunk_attention_ref,
    decode_attention_ref,
)
from .kernels.attention import attention_pallas


def rmsnorm(x, w, eps: float):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jnp.reciprocal(jnp.sqrt(ms + eps)) * w


def rope(x, positions, theta: float):
    """Rotary embedding.  x: [..., N, n_heads, hd]; positions: [N] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )                                                     # [half]
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(angles)[:, None, :]                     # [N, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def qkv_project(x, lp, cfg: ModelConfig, positions):
    """x: [N, D] -> q [H,N,hd], k/v [KV,N,hd] with RoPE applied to q and k.

    Keys are stored *post-RoPE*, so a compressed cache keeps absolute
    positional information no matter which tokens survive selection.
    """
    n = x.shape[0]
    q = (x @ lp["wq"]).reshape(n, cfg.n_heads, cfg.head_dim)
    k = (x @ lp["wk"]).reshape(n, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ lp["wv"]).reshape(n, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return (
        jnp.transpose(q, (1, 0, 2)),
        jnp.transpose(k, (1, 0, 2)),
        jnp.transpose(v, (1, 0, 2)),
    )


def attention_block(x, lp, cfg: ModelConfig, positions, n_valid,
                    kernel: str = "jnp"):
    """Prefill self-attention.  Returns (out [N,D], k/v token-major
    [N,KV,hd], win/acc [H,N])."""
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = qkv_project(h, lp, cfg, positions)
    if kernel == "pallas":
        o, win, acc = attention_pallas(
            q, k, v, n_valid, window=cfg.window, interpret=True
        )
    else:
        o, win, acc = attention_ref(q, k, v, n_valid, window=cfg.window)
    n = x.shape[0]
    o = jnp.transpose(o, (1, 0, 2)).reshape(n, cfg.n_heads * cfg.head_dim)
    out = x + o @ lp["wo"]
    k_tm = jnp.transpose(k, (1, 0, 2))                    # [N, KV, hd]
    v_tm = jnp.transpose(v, (1, 0, 2))
    return out, k_tm, v_tm, win, acc


def chunk_decoder_layer(x, lp, cfg: ModelConfig, positions, k_buf, v_buf,
                        pos0, c_valid, n_valid):
    """One decoder layer over a prompt *chunk* against carried stage-1 KV.

    x [c, D] — hidden states of the chunk (global rows
    ``[pos0, pos0 + c)``); k_buf/v_buf [N, KV, hd] — token-major KV of
    this layer carried from all earlier chunks (rows ``[0, pos0)``
    valid).  The chunk's new keys/values are written into the buffer at
    their global rows in-HLO (same ``jnp.where`` append idiom as
    ``decode_layer_cached``, which also never writes padding rows), then
    the chunk queries attend to the whole buffer under the global causal
    mask — bit-identical to the monolithic ``decoder_layer`` rows.

    Returns (x' [c, D], k_tm/v_tm [c, KV, hd] — the chunk's new KV rows
    for the host-side buffer, win/acc [H, N]).
    """
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = qkv_project(h, lp, cfg, positions)
    k_tm = jnp.transpose(k, (1, 0, 2))                    # [c, KV, hd]
    v_tm = jnp.transpose(v, (1, 0, 2))
    c = x.shape[0]
    n = k_buf.shape[0]
    rows = jnp.arange(n)
    sel = ((rows >= pos0) & (rows < pos0 + c_valid))[:, None, None]
    gidx = jnp.clip(rows - pos0, 0, c - 1)
    k_buf = jnp.where(sel, k_tm[gidx], k_buf)
    v_buf = jnp.where(sel, v_tm[gidx], v_buf)
    o, win, acc = chunk_attention_ref(
        q,
        jnp.transpose(k_buf, (1, 0, 2)),                  # [KV, N, hd]
        jnp.transpose(v_buf, (1, 0, 2)),
        pos0,
        c_valid,
        n_valid,
        window=cfg.window,
    )
    o = jnp.transpose(o, (1, 0, 2)).reshape(c, cfg.n_heads * cfg.head_dim)
    x = x + o @ lp["wo"]
    x = mlp_block(x, lp, cfg)
    return x, k_tm, v_tm, win, acc


def mlp_block(x, lp, cfg: ModelConfig):
    h = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    gate = h @ lp["w_gate"]
    up = h @ lp["w_up"]
    act = gate * jnp.reciprocal(1.0 + jnp.exp(-gate))     # SiLU
    return x + (act * up) @ lp["w_down"]


def layer_params(params: dict, i: int) -> dict:
    prefix = f"l{i}."
    return {k[len(prefix):]: v for k, v in params.items()
            if k.startswith(prefix)}


def decoder_layer(x, lp, cfg: ModelConfig, positions, n_valid,
                  kernel: str = "jnp"):
    x, k, v, win, acc = attention_block(x, lp, cfg, positions, n_valid,
                                        kernel)
    x = mlp_block(x, lp, cfg)
    return x, k, v, win, acc


def decode_layer_cached(x, lp, cfg: ModelConfig, position, k_cache, v_cache,
                        length):
    """Like ``decode_layer`` but the new token's K/V is also attended
    (the cache holds only *past* tokens; self-attention must include the
    current token).  Returns (x', k_new, v_new) with k_new/v_new [KV,hd]."""
    h = rmsnorm(x[None, :], lp["attn_norm"], cfg.norm_eps)
    pos = jnp.reshape(position, (1,)).astype(jnp.int32)
    q, k_new, v_new = qkv_project(h, lp, cfg, pos)
    k_new_t = k_new[:, 0, :]                               # [KV, hd]
    v_new_t = v_new[:, 0, :]
    kc = jnp.transpose(k_cache, (1, 0, 2))                 # [KV, C, hd]
    vc = jnp.transpose(v_cache, (1, 0, 2))
    c = kc.shape[1]
    # Append the current token at slot `length` (capacity reserves room:
    # the rust cache arena always keeps >= 1 free slot when invoking).
    kc = jnp.where(
        (jnp.arange(c)[None, :, None] == length), k_new_t[:, None, :], kc
    )
    vc = jnp.where(
        (jnp.arange(c)[None, :, None] == length), v_new_t[:, None, :], vc
    )
    o = decode_attention_ref(q[:, 0, :], kc, vc, length + 1)
    o = o.reshape(cfg.n_heads * cfg.head_dim)
    x = x + o @ lp["wo"]
    x = mlp_block(x[None, :], lp, cfg)[0]
    return x, k_new_t, v_new_t
