"""Parameter table: deterministic flattening between the JAX pytree, the
single flat f32 vector every artifact takes as input 0, and the
``artifacts/weights.bin`` file the Rust runtime memory-loads.

The flat layout (not a pytree) is deliberate: the PJRT executable then has
exactly one weight input, the Rust side never needs to know shapes, and the
manifest records the table for debugging / checksums.
"""

import numpy as np
import jax.numpy as jnp

from .configs import ModelConfig


def param_specs(cfg: ModelConfig):
    """Ordered list of (name, shape) for every parameter."""
    d, f, v = cfg.d_model, cfg.d_ffn, cfg.vocab_size
    qd = cfg.n_heads * cfg.head_dim
    kd = cfg.n_kv_heads * cfg.head_dim
    specs = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.attn_norm", (d,)),
            (f"l{i}.wq", (d, qd)),
            (f"l{i}.wk", (d, kd)),
            (f"l{i}.wv", (d, kd)),
            (f"l{i}.wo", (qd, d)),
            (f"l{i}.mlp_norm", (d,)),
            (f"l{i}.w_gate", (d, f)),
            (f"l{i}.w_up", (d, f)),
            (f"l{i}.w_down", (f, d)),
        ]
    specs += [("final_norm", (d,)), ("lm_head", (d, v))]
    return specs


def n_params(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for _, s in param_specs(cfg))


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Scaled-normal init (norms at 1)."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_specs(cfg):
        if name.endswith("norm"):
            params[name] = np.ones(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            std = 0.5 / np.sqrt(fan_in) if len(shape) > 1 else 0.02
            if name == "embed":
                std = 0.02
            params[name] = rng.normal(0.0, std, shape).astype(np.float32)
    return params


def flatten(params: dict, cfg: ModelConfig) -> np.ndarray:
    parts = []
    for name, shape in param_specs(cfg):
        arr = np.asarray(params[name], np.float32)
        assert arr.shape == tuple(shape), (name, arr.shape, shape)
        parts.append(arr.ravel())
    return np.concatenate(parts)


def unflatten(flat, cfg: ModelConfig) -> dict:
    """Works on both np arrays and jnp tracers (static offsets)."""
    params = {}
    off = 0
    for name, shape in param_specs(cfg):
        size = int(np.prod(shape))
        params[name] = jnp.reshape(flat[off : off + size], shape)
        off += size
    return params


def save_weights(path: str, params: dict, cfg: ModelConfig) -> None:
    flatten(params, cfg).tofile(path)


def load_weights(path: str, cfg: ModelConfig) -> np.ndarray:
    flat = np.fromfile(path, dtype=np.float32)
    expected = n_params(cfg)
    assert flat.size == expected, (flat.size, expected)
    return flat
