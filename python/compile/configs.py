"""Model and artifact-bucket configurations for the FastKV reproduction.

The paper evaluates LLaMA-3.1-8B / Ministral-8B / Mistral-NeMo-12B. Those are
substituted (see DESIGN.md) by `fastkv-tiny`, a GQA decoder trained at build
time on a synthetic long-context retrieval corpus so that the accuracy /
compression trade-off curves are meaningful.

All artifact shapes are static (AOT, PJRT).  The rust coordinator pads
requests into the buckets declared here and masks the padding.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Architecture of the decoder-only GQA transformer."""

    vocab_size: int = 256          # byte-level tokenizer
    d_model: int = 96
    n_layers: int = 8
    n_heads: int = 4
    n_kv_heads: int = 2            # GQA: 2 query heads per KV head
    d_ffn: int = 192               # SwiGLU hidden size
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # FastKV defaults (paper: layer 15 of 32 -> here 4 of 8, i.e. the first
    # `tsp_layer` layers run full-context, the rest on the TSP token set).
    tsp_layer: int = 4
    # Observation window (paper: 8) and pooling kernel (paper: 7).
    window: int = 8
    pool_kernel: int = 7
    max_train_len: int = 512

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def gqa_groups(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["gqa_groups"] = self.gqa_groups
        return d


@dataclass(frozen=True)
class BucketConfig:
    """Static shape buckets compiled into artifacts."""

    # Full-context prefill buckets (also used by GemFilter's re-prefill, so
    # the small ones must cover TSP/KV budget token counts).
    prefill_ns: tuple = (64, 128, 256, 512, 1024, 2048)
    # FastKV stage-1 buckets (full-context up to the TSP layer).
    stage1_ns: tuple = (256, 512, 1024, 2048)
    # FastKV stage-2 buckets (TSP-selected token count).
    stage2_ns: tuple = (64, 128, 256, 512)
    # Chunked stage-1 (continuous batching): each
    # `prefill_stage1_chunk_{c}x{n}` artifact runs `chunk_c` tokens of the
    # prompt against a carried stage-1 KV buffer of capacity n.  chunk_ns
    # extends past the biggest stage1_ns bucket on purpose: prompts larger
    # than any monolithic bucket still admit — they chunk.
    chunk_c: int = 256
    chunk_ns: tuple = (512, 1024, 2048, 4096)
    # PyramidInfer buckets (per-layer cosine token schedule baked in).
    pyramid_ns: tuple = (256, 512, 1024)
    # Decode artifacts: (batch, kv cache capacity) pairs. Each pair is
    # compiled twice: the dense `decode_{b}x{c}` bridge and the
    # block-table `decode_paged_{b}x{c}` variant (slab + table indices).
    decode_batches: tuple = (1, 4)
    decode_caps: tuple = (128, 320, 576, 1088, 2112)
    # Tokens per physical block of the paged decode artifacts (must match
    # the rust PagingConfig.block_tokens for block-table decode to engage).
    block_tokens: int = 16
    # KV-head shard counts the decode_paged_shard_{b}x{c}s{S} family is
    # compiled for (counts that do not divide n_kv_heads are skipped at
    # emission). Each such artifact takes S separate slab pairs — pinned
    # per shard on the rust side — and returns per-shard k_new/v_new head
    # slices for the host combiner.
    shard_counts: tuple = (2,)
    # Fig-3 / Fig-5(b) sweep: one full-model artifact per candidate TSP layer
    # at this context bucket / TSP token count.
    sweep_n: int = 256
    sweep_nt: int = 64
    # Quickstart artifact built with the Pallas kernel on the hot path.
    pallas_n: int = 128
    max_gen: int = 64


TINY = ModelConfig()

# A smaller config used by pytest so kernel/model unit tests stay fast.
TEST = ModelConfig(
    d_model=32,
    n_layers=4,
    n_heads=2,
    n_kv_heads=1,
    d_ffn=64,
    tsp_layer=2,
    max_train_len=128,
)

BUCKETS = BucketConfig()
