"""AOT lowering: every model entry point -> HLO text artifact + manifest.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (in --out, default ../artifacts):
  *.hlo.txt        one per artifact (DESIGN.md artifact table)
  weights.bin      flat f32 params (written by train.py; a random-init
                   fallback is generated with --allow-random-weights)
  manifest.json    model config + parameter table + artifact registry with
                   full input/output shape signatures for the rust runtime

Run:  cd python && python -m compile.aot [--out DIR] [--fast]
``--fast`` skips the large (N=2048) buckets — used by pytest/CI.
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import TINY, BUCKETS, ModelConfig, BucketConfig
from . import model as M
from .params import n_params, param_specs, init_params, flatten

I32 = jnp.int32
F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    # xla_extension 0.5.1's HLO text parser predates the `largest`
    # attribute on topk (always-largest semantics back then, which is what
    # jax.lax.top_k means) — strip it for compatibility.
    return text.replace(", largest=true", "")


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shapes(entries):
    return [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in entries]


class Emitter:
    def __init__(self, cfg: ModelConfig, out_dir: str):
        self.cfg = cfg
        self.out = out_dir
        self.registry = []
        self.p = n_params(cfg)

    def emit(self, name: str, fn, in_specs, meta: dict):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(text)
        out_shapes = [
            {"shape": list(o.shape), "dtype": str(o.dtype)}
            for o in lowered.out_info
        ]
        entry = {
            "name": name,
            "file": fname,
            "inputs": _shapes(in_specs),
            "outputs": out_shapes,
            **meta,
        }
        self.registry.append(entry)
        print(f"  {name:28s} {len(text)//1024:6d} KiB "
              f"({time.time() - t0:.1f}s)", flush=True)
        return entry


def build(cfg: ModelConfig = TINY, buckets: BucketConfig = BUCKETS,
          out_dir: str = "../artifacts", fast: bool = False,
          kernel: str = "jnp"):
    os.makedirs(out_dir, exist_ok=True)
    em = Emitter(cfg, out_dir)
    P = em.p
    L_, H, KV, hd, D, V = (cfg.n_layers, cfg.n_heads, cfg.n_kv_heads,
                           cfg.head_dim, cfg.d_model, cfg.vocab_size)
    T = cfg.tsp_layer
    max_n = 1024 if fast else max(buckets.prefill_ns)

    flat_s = _spec((P,))

    # --- prefill_full ------------------------------------------------------
    for n in buckets.prefill_ns:
        if n > max_n:
            continue
        fn = functools.partial(M.prefill_full, cfg=cfg, kernel=kernel)
        em.emit(
            f"prefill_full_{n}", fn,
            (flat_s, _spec((n,), I32), _spec((), I32)),
            {"kind": "prefill_full", "n": n, "layers": L_},
        )

    # --- prefill_stage1 / stage2 (FastKV) ----------------------------------
    for n in buckets.stage1_ns:
        if n > max_n:
            continue
        fn = functools.partial(M.prefill_stage1, cfg=cfg, kernel=kernel)
        em.emit(
            f"prefill_stage1_{n}", fn,
            (flat_s, _spec((n,), I32), _spec((), I32)),
            {"kind": "prefill_stage1", "n": n, "layers": T},
        )
    for nt in buckets.stage2_ns:
        if nt > max_n:
            continue
        fn = functools.partial(M.prefill_stage2, cfg=cfg, kernel=kernel)
        em.emit(
            f"prefill_stage2_{nt}", fn,
            (flat_s, _spec((nt, D)), _spec((nt,), I32), _spec((), I32)),
            {"kind": "prefill_stage2", "n": nt, "layers": L_ - T},
        )

    # --- prefill_stage1_chunk (chunked prefill / continuous batching) -------
    # One chunk of `cc` tokens against a carried stage-1 KV buffer of
    # capacity n (which may exceed the biggest monolithic stage1 bucket:
    # prompts too long for any single bucket chunk instead of rejecting).
    # Always emitted with the jnp reference kernel — chunked ≡ monolithic
    # bit-identity is the whole point and is pinned per-bucket by pytest.
    cc = buckets.chunk_c
    chunk_max = 1024 if fast else max(buckets.chunk_ns)
    for n in buckets.chunk_ns:
        if n > chunk_max or n < cc:
            continue
        fn = functools.partial(M.prefill_stage1_chunk, cfg=cfg)
        em.emit(
            f"prefill_stage1_chunk_{cc}x{n}", fn,
            (flat_s, _spec((cc,), I32),
             _spec((T, n, KV, hd)), _spec((T, n, KV, hd)),
             _spec((), I32), _spec((), I32), _spec((), I32)),
            {"kind": "prefill_stage1_chunk", "n": n, "chunk": cc,
             "layers": T},
        )

    # --- prefill_pyramid (PyramidInfer baseline) ---------------------------
    for n in buckets.pyramid_ns:
        if n > max_n:
            continue
        fn = functools.partial(M.prefill_pyramid, cfg=cfg, kernel=kernel)
        em.emit(
            f"prefill_pyramid_{n}", fn,
            (flat_s, _spec((n,), I32), _spec((), I32)),
            {"kind": "prefill_pyramid", "n": n, "layers": L_,
             "schedule": M.pyramid_schedule(cfg, n)},
        )

    # --- decode_step --------------------------------------------------------
    for b in buckets.decode_batches:
        for c in buckets.decode_caps:
            if c > max_n + buckets.max_gen:
                continue
            fn = functools.partial(M.decode_step, cfg=cfg)
            em.emit(
                f"decode_{b}x{c}", fn,
                (flat_s, _spec((b,), I32), _spec((b,), I32),
                 _spec((L_, b, c, KV, hd)), _spec((L_, b, c, KV, hd)),
                 _spec((L_, b), I32)),
                {"kind": "decode", "batch": b, "cap": c},
            )

    # --- decode_paged_step (block-table decode over the slab) ---------------
    # The slab bucket NB is the worst case L * B * ceil(C / bt): a rust-side
    # pool sized any smaller is zero-padded up at (version-cached) upload.
    bt = buckets.block_tokens
    for b in buckets.decode_batches:
        for c in buckets.decode_caps:
            if c > max_n + buckets.max_gen:
                continue
            mb = -(-c // bt)  # ceil
            nb = L_ * b * mb
            fn = functools.partial(M.decode_paged_step, cfg=cfg)
            em.emit(
                f"decode_paged_{b}x{c}", fn,
                (flat_s, _spec((b,), I32), _spec((b,), I32),
                 _spec((nb, bt, KV, hd)), _spec((nb, bt, KV, hd)),
                 _spec((L_, b, mb), I32), _spec((L_, b), I32)),
                {"kind": "decode_paged", "batch": b, "cap": c,
                 "pool_blocks": nb, "block_tokens": bt},
            )

    # --- decode_paged_q8_step (int8 slab + per-row scales, in-HLO dequant) --
    # Same slab/table buckets as decode_paged; the quantized planes travel
    # as integer-valued f32 (the runtime ABI is f32-only) with one
    # [NB, bt] scale tensor per plane.
    for b in buckets.decode_batches:
        for c in buckets.decode_caps:
            if c > max_n + buckets.max_gen:
                continue
            mb = -(-c // bt)  # ceil
            nb = L_ * b * mb
            fn = functools.partial(M.decode_paged_q8_step, cfg=cfg)
            em.emit(
                f"decode_paged_q8_{b}x{c}", fn,
                (flat_s, _spec((b,), I32), _spec((b,), I32),
                 _spec((nb, bt, KV, hd)), _spec((nb, bt)),
                 _spec((nb, bt, KV, hd)), _spec((nb, bt)),
                 _spec((L_, b, mb), I32), _spec((L_, b), I32)),
                {"kind": "decode_paged_q8", "batch": b, "cap": c,
                 "pool_blocks": nb, "block_tokens": bt},
            )

    # --- decode_paged_shard_step (KV-head-sharded block-table decode) -------
    # One artifact per (batch, cap, S): S slab pairs of [NB, bt, KV/S, hd]
    # (pinned per shard by the rust runtime), shared tables/lens; outputs
    # per-shard k_new/v_new slices for the host-side combiner. Shard
    # counts that do not divide KV are skipped (the rust config layer
    # rejects them too).
    shard_counts = [s for s in buckets.shard_counts
                    if s > 1 and KV % s == 0]
    for b in buckets.decode_batches:
        for c in buckets.decode_caps:
            if c > max_n + buckets.max_gen:
                continue
            mb = -(-c // bt)  # ceil
            nb = L_ * b * mb
            for s in shard_counts:
                kvs = KV // s
                fn = functools.partial(M.decode_paged_shard_step, cfg=cfg,
                                       shards=s)
                slab_specs = []
                for _ in range(s):
                    slab_specs += [_spec((nb, bt, kvs, hd)),
                                   _spec((nb, bt, kvs, hd))]
                em.emit(
                    f"decode_paged_shard_{b}x{c}s{s}", fn,
                    (flat_s, _spec((b,), I32), _spec((b,), I32),
                     *slab_specs,
                     _spec((L_, b, mb), I32), _spec((L_, b), I32)),
                    {"kind": "decode_paged_shard", "batch": b, "cap": c,
                     "pool_blocks": nb, "block_tokens": bt,
                     "shards": s, "shard_kv_heads": kvs},
                )
                # Quantized twin: per shard, (q-K plane, K scales, q-V
                # plane, V scales); the scales are per *full* row, shared
                # by all shards of the row.
                fn = functools.partial(M.decode_paged_q8_shard_step,
                                       cfg=cfg, shards=s)
                q8_specs = []
                for _ in range(s):
                    q8_specs += [_spec((nb, bt, kvs, hd)), _spec((nb, bt)),
                                 _spec((nb, bt, kvs, hd)), _spec((nb, bt))]
                em.emit(
                    f"decode_paged_q8_shard_{b}x{c}s{s}", fn,
                    (flat_s, _spec((b,), I32), _spec((b,), I32),
                     *q8_specs,
                     _spec((L_, b, mb), I32), _spec((L_, b), I32)),
                    {"kind": "decode_paged_q8_shard", "batch": b, "cap": c,
                     "pool_blocks": nb, "block_tokens": bt,
                     "shards": s, "shard_kv_heads": kvs},
                )

    # --- sweep_tsp (Fig. 3 / Fig. 5b / Table 10) ----------------------------
    n, nt = buckets.sweep_n, buckets.sweep_nt
    for t in range(1, cfg.n_layers):
        fn = functools.partial(M.sweep_tsp, cfg=cfg, t=t, nt=nt,
                               kernel=kernel)
        em.emit(
            f"sweep_tsp_l{t}_{n}", fn,
            (flat_s, _spec((n,), I32), _spec((), I32)),
            {"kind": "sweep_tsp", "n": n, "nt": nt, "tsp_layer": t},
        )

    # --- Pallas-kernel artifact (L1 on the hot path, quickstart + tests) ----
    n = buckets.pallas_n
    fn = functools.partial(M.prefill_full, cfg=cfg, kernel="pallas")
    em.emit(
        f"prefill_pallas_{n}", fn,
        (flat_s, _spec((n,), I32), _spec((), I32)),
        {"kind": "prefill_pallas", "n": n, "layers": L_},
    )

    manifest = {
        "model": cfg.to_dict(),
        "n_params": P,
        "kernel": kernel,
        "buckets": {
            "prefill_ns": [x for x in buckets.prefill_ns if x <= max_n],
            "stage1_ns": [x for x in buckets.stage1_ns if x <= max_n],
            "stage2_ns": [x for x in buckets.stage2_ns if x <= max_n],
            "chunk_c": buckets.chunk_c,
            "chunk_ns": [
                x for x in buckets.chunk_ns
                if x <= (1024 if fast else max(buckets.chunk_ns))
                and x >= buckets.chunk_c
            ],
            "pyramid_ns": [x for x in buckets.pyramid_ns if x <= max_n],
            "decode_batches": list(buckets.decode_batches),
            "decode_caps": [
                c for c in buckets.decode_caps
                if c <= max_n + buckets.max_gen
            ],
            "sweep_n": buckets.sweep_n,
            "sweep_nt": buckets.sweep_nt,
            "pallas_n": buckets.pallas_n,
            "max_gen": buckets.max_gen,
            "block_tokens": buckets.block_tokens,
            "shard_counts": [s for s in buckets.shard_counts
                             if s > 1 and cfg.n_kv_heads % s == 0],
        },
        "params": [
            {"name": name, "shape": list(shape)}
            for name, shape in param_specs(cfg)
        ],
        "artifacts": em.registry,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(em.registry)} artifacts")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="skip N>1024 buckets (CI)")
    ap.add_argument("--kernel", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--allow-random-weights", action="store_true",
                    help="write random-init weights.bin if none exists")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    wpath = os.path.join(args.out, "weights.bin")
    if not os.path.exists(wpath):
        if args.allow_random_weights:
            print("weights.bin missing -> writing random init "
                  "(train with compile.train for real results)")
            flatten(init_params(TINY, 0), TINY).tofile(wpath)
        else:
            raise SystemExit(
                f"{wpath} missing: run `python -m compile.train` first "
                "or pass --allow-random-weights"
            )
    build(TINY, BUCKETS, args.out, fast=args.fast, kernel=args.kernel)


if __name__ == "__main__":
    main()
