"""L1 Pallas kernel: fused causal GQA attention + saliency summaries.

The paper's hot spot is FlashAttention-2 on A100 plus a token-importance
estimation pass (observation-window attention scores, Eq. 1).  FastKV's
Table 8 shows that estimation must be ~free (<2% of prefill).  The TPU
re-think (DESIGN.md §Hardware-Adaptation): the win/acc score summaries are
row-reductions over exactly the probability tiles the attention kernel
already holds in VMEM, so we fuse them into the attention kernel — zero
extra HBM traffic.

Blocking scheme: the grid walks (query head, query block).  For each query
head, the full K/V rows of its GQA key head stay resident in VMEM
(N*hd*4 bytes, ≤192 KiB at our largest bucket — far below the ~16 MiB VMEM
budget) while Q streams through in ``block_q`` row tiles.  The win/acc
output rows are revisited by every query block of a head and accumulated
in place (grid iteration is sequential over the minor axis).  On a real
TPU the same schedule maps to a Mosaic kernel with the MXU doing the
[block_q, hd] x [hd, N] and [block_q, N] x [N, hd] matmuls in bf16; here
``interpret=True`` is mandatory because the CPU PJRT plugin cannot execute
Mosaic custom-calls.

Correctness oracle: ``ref.attention_ref`` (pure jnp); pytest + hypothesis
sweep shapes/valid-lengths/dtypes against it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(nv_ref, q_ref, k_ref, v_ref, o_ref, win_ref, acc_ref, *,
                 block_q: int, window: int, n: int):
    qi = pl.program_id(1)
    n_valid = nv_ref[0]

    q = q_ref[0]                       # [block_q, hd]
    k = k_ref[0]                       # [n, hd]
    v = v_ref[0]                       # [n, hd]
    hd = q.shape[-1]

    row = qi * block_q + jax.lax.iota(jnp.int32, block_q)     # global q idx
    col = jax.lax.iota(jnp.int32, n)

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=jnp.float32))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    causal = col[None, :] <= row[:, None]
    kvalid = col[None, :] < n_valid
    s = jnp.where(causal & kvalid, s, -1e30)

    # Row softmax (full key row is resident, so no online rescaling needed;
    # the streaming-K variant is analyzed in EXPERIMENTS.md §Perf).
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)

    qvalid = (row < n_valid).astype(jnp.float32)              # [block_q]
    p = p * qvalid[:, None]

    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)

    # Fused saliency summaries: column reductions of the same p tile.
    in_win = ((row >= n_valid - window) & (row < n_valid)).astype(
        jnp.float32
    )
    win_part = jnp.einsum("qk,q->k", p, in_win)
    acc_part = jnp.sum(p, axis=0)

    @pl.when(qi == 0)
    def _init():
        win_ref[0] = jnp.zeros_like(win_ref[0])
        acc_ref[0] = jnp.zeros_like(acc_ref[0])

    win_ref[0] += win_part
    acc_ref[0] += acc_part


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "interpret")
)
def attention_pallas(q, k, v, n_valid, *, window: int, block_q: int = 64,
                     interpret: bool = True):
    """Fused attention + saliency summaries.  Same contract as
    ``ref.attention_ref`` — q [H,N,hd], k/v [KV,N,hd], n_valid scalar i32;
    returns (o [H,N,hd], win [H,N], acc [H,N])."""
    h, n, hd = q.shape
    kv = k.shape[0]
    groups = h // kv
    assert h == kv * groups
    block_q = min(block_q, n)
    assert n % block_q == 0, (n, block_q)
    grid = (h, n // block_q)

    nv = jnp.reshape(n_valid.astype(jnp.int32), (1,))

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, window=window, n=n
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda hi, qi: (0,)),                  # n_valid
            pl.BlockSpec((1, block_q, hd), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((1, n, hd), lambda hi, qi: (hi // groups, 0, 0)),
            pl.BlockSpec((1, n, hd), lambda hi, qi: (hi // groups, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((1, n), lambda hi, qi: (hi, 0)),
            pl.BlockSpec((1, n), lambda hi, qi: (hi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, n, hd), jnp.float32),
            jax.ShapeDtypeStruct((h, n), jnp.float32),
            jax.ShapeDtypeStruct((h, n), jnp.float32),
        ],
        interpret=interpret,
    )(nv, q, k, v)


def vmem_bytes(n: int, hd: int, block_q: int) -> int:
    """Static VMEM footprint estimate for one kernel instance (f32).

    Used by the §Perf analysis: resident K/V rows + Q/O tiles + the
    probability tile + score rows.
    """
    kv_resident = 2 * n * hd * 4
    q_o_tiles = 2 * block_q * hd * 4
    p_tile = block_q * n * 4
    score_rows = 2 * n * 4
    return kv_resident + q_o_tiles + p_tile + score_rows


def mxu_flops(n: int, hd: int) -> int:
    """MACs issued to the MXU for one head's prefill attention."""
    return 2 * n * n * hd * 2  # QK^T and PV
