"""Pure-jnp oracle for the fused attention + saliency-summary kernel.

This is the correctness ground truth for the Pallas kernel in
``attention.py`` and also the implementation used on the default (fast) HLO
artifact path — both lower to identical math, and pytest asserts the Pallas
kernel matches this reference to float tolerance.

Semantics (causal GQA prefill attention over one sequence):

  inputs   q         [H,  N, hd]   query heads
           k, v      [KV, N, hd]   key/value heads (GQA: H = KV * groups)
           n_valid   scalar int32  number of non-padding tokens (<= N)
           window    static int    observation window W (paper: 8)
  outputs  o         [H,  N, hd]   attention output
           win       [H,  N]      attention mass each position receives from
                                   the last W *valid* query positions (Eq. 1
                                   of the paper, pre-pooling)
           acc       [H,  N]      total attention mass received from all
                                   valid queries (H2O-style accumulated score,
                                   also feeds the Fig. 1 analyses)

Padding behaviour: rows (queries) with index >= n_valid produce zeros and
contribute nothing to win/acc; columns (keys) with index >= n_valid receive
zero attention.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, n_valid, *, window: int):
    h, n, hd = q.shape
    kv = k.shape[0]
    groups = h // kv
    assert h == kv * groups

    # Broadcast KV heads across their query-head groups: [H, N, hd].
    k_full = jnp.repeat(k, groups, axis=0)
    v_full = jnp.repeat(v, groups, axis=0)

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=jnp.float32))
    scores = jnp.einsum("hqd,hkd->hqk", q, k_full) * scale  # [H, N, N]

    idx = jnp.arange(n)
    causal = idx[None, :] <= idx[:, None]                  # [q, k]
    key_valid = idx[None, :] < n_valid                     # [1, k]
    mask = causal & key_valid                              # [q, k]
    scores = jnp.where(mask[None], scores, -1e30)

    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)

    # Zero out padded query rows entirely.
    q_valid = (idx < n_valid).astype(jnp.float32)          # [q]
    p = p * q_valid[None, :, None]

    o = jnp.einsum("hqk,hkd->hqd", p, v_full)

    acc = jnp.sum(p, axis=1)                               # [H, N]
    # Observation window: queries in [n_valid - W, n_valid).
    in_window = ((idx >= n_valid - window) & (idx < n_valid)).astype(
        jnp.float32
    )                                                      # [q]
    win = jnp.einsum("hqk,q->hk", p, in_window)            # [H, N]
    return o, win, acc


def maxpool1d_ref(x, kernel: int):
    """Max-pool along the last axis with 'same' padding (paper kernel 7).

    Matches the torch ``MaxPool1d(kernel, stride=1, padding=kernel//2)`` the
    SnapKV/FastKV reference implementations use.
    """
    assert kernel % 2 == 1
    pad = kernel // 2
    n = x.shape[-1]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(pad, pad)],
                 constant_values=-jnp.inf)
    cols = [xp[..., i : i + n] for i in range(kernel)]
    return jnp.max(jnp.stack(cols, axis=0), axis=0)


def decode_attention_ref(q, k_cache, v_cache, lens):
    """Single-token decode attention over a (compressed) KV cache.

    q        [H, hd]        query for the new token (one sequence)
    k_cache  [KV, C, hd]    cache capacity C, entries [0, len) are valid
    v_cache  [KV, C, hd]
    lens     scalar int32   number of valid cache entries
    returns  o [H, hd]
    """
    h, hd = q.shape
    kv, c, _ = k_cache.shape
    groups = h // kv
    k_full = jnp.repeat(k_cache, groups, axis=0)
    v_full = jnp.repeat(v_cache, groups, axis=0)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=jnp.float32))
    scores = jnp.einsum("hd,hkd->hk", q, k_full) * scale   # [H, C]
    valid = jnp.arange(c)[None, :] < lens
    scores = jnp.where(valid, scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hk,hkd->hd", p, v_full)
