"""Pure-jnp oracle for the fused attention + saliency-summary kernel.

This is the correctness ground truth for the Pallas kernel in
``attention.py`` and also the implementation used on the default (fast) HLO
artifact path — both lower to identical math, and pytest asserts the Pallas
kernel matches this reference to float tolerance.

Semantics (causal GQA prefill attention over one sequence):

  inputs   q         [H,  N, hd]   query heads
           k, v      [KV, N, hd]   key/value heads (GQA: H = KV * groups)
           n_valid   scalar int32  number of non-padding tokens (<= N)
           window    static int    observation window W (paper: 8)
  outputs  o         [H,  N, hd]   attention output
           win       [H,  N]      attention mass each position receives from
                                   the last W *valid* query positions (Eq. 1
                                   of the paper, pre-pooling)
           acc       [H,  N]      total attention mass received from all
                                   valid queries (H2O-style accumulated score,
                                   also feeds the Fig. 1 analyses)

Padding behaviour: rows (queries) with index >= n_valid produce zeros and
contribute nothing to win/acc; columns (keys) with index >= n_valid receive
zero attention.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, n_valid, *, window: int):
    h, n, hd = q.shape
    kv = k.shape[0]
    groups = h // kv
    assert h == kv * groups

    # Broadcast KV heads across their query-head groups: [H, N, hd].
    k_full = jnp.repeat(k, groups, axis=0)
    v_full = jnp.repeat(v, groups, axis=0)

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=jnp.float32))
    scores = jnp.einsum("hqd,hkd->hqk", q, k_full) * scale  # [H, N, N]

    idx = jnp.arange(n)
    causal = idx[None, :] <= idx[:, None]                  # [q, k]
    key_valid = idx[None, :] < n_valid                     # [1, k]
    mask = causal & key_valid                              # [q, k]
    scores = jnp.where(mask[None], scores, -1e30)

    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)

    # Zero out padded query rows entirely.
    q_valid = (idx < n_valid).astype(jnp.float32)          # [q]
    p = p * q_valid[None, :, None]

    o = jnp.einsum("hqk,hkd->hqd", p, v_full)

    acc = jnp.sum(p, axis=1)                               # [H, N]
    # Observation window: queries in [n_valid - W, n_valid).
    in_window = ((idx >= n_valid - window) & (idx < n_valid)).astype(
        jnp.float32
    )                                                      # [q]
    win = jnp.einsum("hqk,q->hk", p, in_window)            # [H, N]
    return o, win, acc


def chunk_attention_ref(q, k, v, pos0, c_valid, n_valid, *, window: int):
    """Chunked causal GQA prefill attention against a carried KV buffer.

    q        [H, c, hd]    queries of one chunk (global rows
                           ``[pos0, pos0 + c)`` of the sequence)
    k, v     [KV, N, hd]   the *full* stage-1 KV buffer: rows
                           ``[0, pos0 + c_valid)`` hold carried + current
                           chunk keys, later rows are ignored (masked)
    pos0     scalar int32  global position of the chunk's first token
    c_valid  scalar int32  valid (non-padding) tokens in this chunk
    n_valid  scalar int32  valid tokens in the whole sequence
    returns  (o [H, c, hd], win [H, N], acc [H, N])

    Bit-identity with ``attention_ref`` is deliberate, not approximate:
    the key axis keeps the full bucket length ``N`` so every softmax /
    value reduction has the monolithic shape, and ``win``/``acc`` reduce
    over a ``[H, N, N]`` probability tensor with the chunk rows placed at
    their global offsets, so the query-axis reduction tree is the
    monolithic one with exact zeros elsewhere. ``win`` therefore equals
    the monolithic ``win`` bitwise on whichever chunk contains the whole
    observation window (the last chunk, by the rust driver's span rule);
    ``acc`` is the chunk-partial sum (its consumers only ever read it
    from ``prefill_full``).
    """
    h, c, hd = q.shape
    kv, n, _ = k.shape
    groups = h // kv
    assert h == kv * groups

    k_full = jnp.repeat(k, groups, axis=0)
    v_full = jnp.repeat(v, groups, axis=0)

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=jnp.float32))
    scores = jnp.einsum("hqd,hkd->hqk", q, k_full) * scale  # [H, c, N]

    kidx = jnp.arange(n)
    qpos = pos0 + jnp.arange(c)                            # global rows
    causal = kidx[None, :] <= qpos[:, None]                # [q, k]
    key_valid = kidx[None, :] < n_valid                    # [1, k]
    mask = causal & key_valid
    scores = jnp.where(mask[None], scores, -1e30)

    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)

    q_valid = (jnp.arange(c) < c_valid).astype(jnp.float32)
    p = p * q_valid[None, :, None]

    o = jnp.einsum("hqk,hkd->hqd", p, v_full)

    # Place the chunk's probability rows at their global offsets so the
    # win/acc reductions run over the exact monolithic [H, N, N] shape.
    rows = jnp.arange(n)
    gidx = jnp.clip(rows - pos0, 0, c - 1)
    sel = (rows >= pos0) & (rows < pos0 + c_valid)
    p_full = jnp.where(sel[None, :, None], p[:, gidx, :], 0.0)

    acc = jnp.sum(p_full, axis=1)                          # [H, N]
    in_window = ((rows >= n_valid - window) & (rows < n_valid)).astype(
        jnp.float32
    )
    win = jnp.einsum("hqk,q->hk", p_full, in_window)       # [H, N]
    return o, win, acc


def maxpool1d_ref(x, kernel: int):
    """Max-pool along the last axis with 'same' padding (paper kernel 7).

    Matches the torch ``MaxPool1d(kernel, stride=1, padding=kernel//2)`` the
    SnapKV/FastKV reference implementations use.
    """
    assert kernel % 2 == 1
    pad = kernel // 2
    n = x.shape[-1]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(pad, pad)],
                 constant_values=-jnp.inf)
    cols = [xp[..., i : i + n] for i in range(kernel)]
    return jnp.max(jnp.stack(cols, axis=0), axis=0)


def decode_attention_ref(q, k_cache, v_cache, lens):
    """Single-token decode attention over a (compressed) KV cache.

    q        [H, hd]        query for the new token (one sequence)
    k_cache  [KV, C, hd]    cache capacity C, entries [0, len) are valid
    v_cache  [KV, C, hd]
    lens     scalar int32   number of valid cache entries
    returns  o [H, hd]
    """
    h, hd = q.shape
    kv, c, _ = k_cache.shape
    groups = h // kv
    k_full = jnp.repeat(k_cache, groups, axis=0)
    v_full = jnp.repeat(v_cache, groups, axis=0)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=jnp.float32))
    scores = jnp.einsum("hd,hkd->hk", q, k_full) * scale   # [H, C]
    valid = jnp.arange(c)[None, :] < lens
    scores = jnp.where(valid, scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hk,hkd->hd", p, v_full)
