"""Build-time training of the fastkv-tiny substrate model.

Trains the GQA decoder on the synthetic long-context retrieval corpus
(data.py) with a hand-rolled Adam (optax is not available in this
environment).  Loss is masked cross-entropy over answer bytes only, which
makes retrieval behaviour emerge quickly at tiny scale.

Outputs:
  artifacts/weights.bin    flat f32 parameter vector (params.py order)
  artifacts/train_log.json loss curve + teacher-forced answer accuracy
                           (recorded in EXPERIMENTS.md)

Run:  cd python && python -m compile.train [--steps N] [--out DIR]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .configs import TINY, ModelConfig
from . import data
from .model import forward_train
from .params import init_params, flatten, n_params


def masked_ce(flat, tokens, mask, cfg: ModelConfig):
    logits = forward_train(flat, tokens, cfg=cfg)       # [B, N, V]
    targets = tokens[:, 1:]                             # next byte
    logits = logits[:, :-1]
    mask = mask[:, :-1]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(
        logits, targets[..., None], axis=-1
    )[..., 0]
    nll = lse - tgt_logit
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def answer_accuracy(flat, tokens, mask, cfg: ModelConfig):
    """Teacher-forced accuracy on answer bytes (cheap eval proxy)."""
    logits = forward_train(flat, tokens, cfg=cfg)
    pred = jnp.argmax(logits[:, :-1], axis=-1)
    hit = (pred == tokens[:, 1:]).astype(jnp.float32) * mask[:, :-1]
    return jnp.sum(hit) / jnp.maximum(jnp.sum(mask[:, :-1]), 1.0)


def make_step(cfg: ModelConfig, lr_base: float, total_steps: int,
              warmup: int):
    loss_grad = jax.value_and_grad(masked_ce)

    @jax.jit
    def step(flat, m, v, t, tokens, mask):
        loss, g = loss_grad(flat, tokens, mask, cfg)
        lr = lr_base * jnp.minimum(1.0, t / warmup) * (
            0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(t / total_steps, 1.0)))
            * 0.9 + 0.1
        )
        b1, b2, eps = 0.9, 0.95, 1e-8
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        flat = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
        return flat, m, v, loss

    return step


def train(cfg: ModelConfig = TINY, steps: int = 1200, batch_size: int = 8,
          seq_len: int = 256, long_steps: int = 150, long_len: int = 512,
          lr: float = 1.5e-3, seed: int = 0, out_dir: str = "../artifacts",
          log_every: int = 25, init_from: str = None):
    rng = np.random.default_rng(seed)
    if init_from:
        from .params import load_weights
        flat = jnp.asarray(load_weights(init_from, cfg))
        print(f"resumed from {init_from}")
    else:
        flat = jnp.asarray(flatten(init_params(cfg, seed), cfg))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    total = steps + long_steps
    step_fn = make_step(cfg, lr, total, warmup=max(total // 20, 20))
    acc_fn = jax.jit(lambda f, t, msk: answer_accuracy(f, t, msk, cfg))

    log = {"config": cfg.to_dict(), "n_params": n_params(cfg),
           "steps": [], "loss": [], "acc": [], "phase": []}
    t0 = time.time()
    for t in range(1, total + 1):
        phase_long = t > steps
        sl = long_len if phase_long else seq_len
        bs = max(batch_size // (long_len // seq_len), 2) if phase_long \
            else batch_size
        tokens, mask = data.batch(rng, bs, sl)
        flat, m, v, loss = step_fn(
            flat, m, v, jnp.float32(t), jnp.asarray(tokens),
            jnp.asarray(mask)
        )
        if t % log_every == 0 or t == total:
            tokens_e, mask_e = data.batch(rng, 8, sl)
            acc = float(acc_fn(flat, jnp.asarray(tokens_e),
                               jnp.asarray(mask_e)))
            log["steps"].append(t)
            log["loss"].append(float(loss))
            log["acc"].append(acc)
            log["phase"].append("long" if phase_long else "base")
            el = time.time() - t0
            print(f"step {t:5d}/{total}  len={sl:4d}  loss={float(loss):.4f}"
                  f"  ans_acc={acc:.3f}  ({el:.0f}s)", flush=True)
        if t % 200 == 0:
            # periodic checkpoint so interrupted runs keep progress
            os.makedirs(out_dir, exist_ok=True)
            np.asarray(flat, np.float32).tofile(
                os.path.join(out_dir, "weights.bin")
            )

    os.makedirs(out_dir, exist_ok=True)
    wpath = os.path.join(out_dir, "weights.bin")
    np.asarray(flat, np.float32).tofile(wpath)
    log["wall_seconds"] = time.time() - t0
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump(log, f, indent=1)
    print(f"saved {wpath} ({flat.size} params)")
    return np.asarray(flat)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1200)
    ap.add_argument("--long-steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--lr", type=float, default=1.5e-3)
    ap.add_argument("--init-from", default=None,
                    help="resume from an existing weights.bin")
    args = ap.parse_args()
    train(TINY, steps=args.steps, batch_size=args.batch, seq_len=args.seq,
          long_steps=args.long_steps, seed=args.seed, out_dir=args.out,
          lr=args.lr, init_from=args.init_from)


if __name__ == "__main__":
    main()
