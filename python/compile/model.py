"""L2 model: every AOT entry point the Rust coordinator executes.

All entry points take the flat f32 parameter vector as their first input
(see ``params.py``) and static-shaped tensors otherwise; rust pads into the
shape buckets of ``configs.BucketConfig`` and passes ``n_valid`` masks.

Entry points (see DESIGN.md artifact table):
  prefill_full    — full-context prefill, all layers.  Baselines + analyses.
  prefill_stage1  — FastKV stage 1: layers [0, T) full-context.
  prefill_stage1_chunk — chunked stage 1: one chunk of tokens attending to
                    the carried KV of all earlier chunks (bit-identical to
                    the same rows of prefill_stage1; enables chunked
                    prefill interleaved with decode in the serve loop).
  prefill_stage2  — FastKV stage 2: layers [T, L) over TSP-selected hiddens.
  prefill_pyramid — PyramidInfer: per-layer cosine token-count schedule.
  decode_step     — batched single-token decode over compressed caches.
  decode_paged_step — block-table decode: the same math, but the KV inputs
                    are the paged block slab plus per-(layer, lane) block
                    tables (gather in HLO), so the host never densifies
                    the pool.
  decode_paged_shard_step — KV-head-sharded block-table decode: S separate
                    slab pairs (one per shard, pinned per shard on the
                    rust side) concatenated head-wise in HLO; outputs
                    per-shard k_new/v_new slices for the host combiner.
  sweep_tsp       — full model with TSP applied *inside* HLO at layer t
                    (Fig. 3 / Fig. 5(b) / Table 10 sweeps).

KV outputs are token-major [layers, N, KV, hd] so that selecting a token's
KV entry is one contiguous row copy on the rust side.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from . import layers as L
from .params import unflatten
from .kernels.ref import maxpool1d_ref


def _embed(params, tokens):
    return params["embed"][tokens]


def _final_logits_at(params, cfg, x, idx):
    """Logits of position ``idx`` (dynamic) of hidden states x [N, D]."""
    h = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    last = jax.lax.dynamic_index_in_dim(h, idx, axis=0, keepdims=False)
    return last @ params["lm_head"], last


def _run_layers(params, cfg, x, positions, n_valid, lo, hi, kernel):
    """Run layers [lo, hi); stack KV and score outputs over that range."""
    ks, vs, wins, accs = [], [], [], []
    for i in range(lo, hi):
        lp = L.layer_params(params, i)
        x, k, v, win, acc = L.decoder_layer(
            x, lp, cfg, positions, n_valid, kernel
        )
        ks.append(k)
        vs.append(v)
        wins.append(win)
        accs.append(acc)
    return (
        x,
        jnp.stack(ks),       # [hi-lo, N, KV, hd]
        jnp.stack(vs),
        jnp.stack(wins),     # [hi-lo, H, N]
        jnp.stack(accs),
    )


def prefill_full(flat, tokens, n_valid, *, cfg: ModelConfig,
                 kernel: str = "jnp"):
    """tokens [N] i32, n_valid scalar i32 ->
    (logits [V], k [L,N,KV,hd], v, win [L,H,N], acc [L,H,N], final_h [D])"""
    params = unflatten(flat, cfg)
    n = tokens.shape[0]
    positions = jnp.arange(n, dtype=jnp.int32)
    x = _embed(params, tokens)
    x, k, v, win, acc = _run_layers(
        params, cfg, x, positions, n_valid, 0, cfg.n_layers, kernel
    )
    logits, final_h = _final_logits_at(params, cfg, x, n_valid - 1)
    return logits, k, v, win, acc, final_h


def prefill_stage1(flat, tokens, n_valid, *, cfg: ModelConfig,
                   kernel: str = "jnp"):
    """FastKV stage 1 — layers [0, T) on the full context.

    tokens [N], n_valid ->
    (hidden [N,D], k [T,N,KV,hd], v, win [T,H,N], acc [T,H,N])

    ``hidden`` is the input to layer T; the rust coordinator performs the
    TSP selection (Eq. 1-2: head-average + max-pool + top-k + window merge)
    on ``win[T-1]`` and gathers the selected rows for stage 2.
    """
    params = unflatten(flat, cfg)
    n = tokens.shape[0]
    positions = jnp.arange(n, dtype=jnp.int32)
    x = _embed(params, tokens)
    x, k, v, win, acc = _run_layers(
        params, cfg, x, positions, n_valid, 0, cfg.tsp_layer, kernel
    )
    return x, k, v, win, acc


def prefill_stage1_chunk(flat, tokens, k_buf, v_buf, pos0, c_valid, n_valid,
                         *, cfg: ModelConfig):
    """FastKV stage 1 over one prompt *chunk* with a carried KV prefix.

    tokens [c] i32 — token ids of global rows ``[pos0, pos0 + c)``;
    k_buf/v_buf [T, N, KV, hd] — token-major stage-1 KV carried from all
    earlier chunks (rows ``[0, pos0)`` valid, the rest ignored);
    pos0 / c_valid / n_valid — scalar i32: chunk origin, valid tokens in
    this chunk, valid tokens in the whole sequence ->
    (hidden [c, D], k_c [T, c, KV, hd], v_c, win [T, H, N], acc [T, H, N])

    Causality makes this *bit-identical* to the same rows of the
    monolithic ``prefill_stage1`` (pinned by
    ``python/tests/test_model.py::TestChunkedStage1``): each chunk row
    only ever attends to rows at or before it, all of which are either in
    the carried buffer or in the chunk itself, and every reduction keeps
    the monolithic shape (key axis ``N``; see ``chunk_attention_ref``).
    The rust chunked driver (``policies.rs``) copies ``k_c``/``v_c`` back
    into its host-side buffer after each call and takes ``win`` from the
    final chunk, whose span is arranged to contain the whole observation
    window. Chunks use the jnp reference kernel only (the Pallas prefill
    kernel has no carried-KV variant).
    """
    params = unflatten(flat, cfg)
    c = tokens.shape[0]
    positions = pos0 + jnp.arange(c, dtype=jnp.int32)
    x = _embed(params, tokens)
    ks, vs, wins, accs = [], [], [], []
    for i in range(cfg.tsp_layer):
        lp = L.layer_params(params, i)
        x, k_tm, v_tm, win, acc = L.chunk_decoder_layer(
            x, lp, cfg, positions, k_buf[i], v_buf[i], pos0, c_valid,
            n_valid
        )
        ks.append(k_tm)
        vs.append(v_tm)
        wins.append(win)
        accs.append(acc)
    return (
        x,
        jnp.stack(ks),       # [T, c, KV, hd]
        jnp.stack(vs),
        jnp.stack(wins),     # [T, H, N]
        jnp.stack(accs),
    )


def prefill_stage2(flat, hidden, positions, nt_valid, *, cfg: ModelConfig,
                   kernel: str = "jnp"):
    """FastKV stage 2 — layers [T, L) over the TSP-selected hidden states.

    hidden [Nt,D], positions [Nt] i32 (original token positions, ascending),
    nt_valid scalar ->
    (logits [V], k [L-T,Nt,KV,hd], v, win [L-T,H,Nt], acc, final_h [D])
    """
    params = unflatten(flat, cfg)
    x, k, v, win, acc = _run_layers(
        params, cfg, hidden, positions, nt_valid, cfg.tsp_layer,
        cfg.n_layers, kernel
    )
    logits, final_h = _final_logits_at(params, cfg, x, nt_valid - 1)
    return logits, k, v, win, acc, final_h


def pyramid_schedule(cfg: ModelConfig, n: int, min_rate: float = 0.6):
    """PyramidInfer's cosine decay of per-layer token counts.

    Layer 0 keeps everything; the count decays on a cosine down to
    ``min_rate * n`` at the last layer (the paper's 60% prefill-compute
    operating point).  Static — baked into the artifact.
    """
    import math

    counts = []
    for i in range(cfg.n_layers):
        t = i / max(cfg.n_layers - 1, 1)
        rate = min_rate + (1.0 - min_rate) * 0.5 * (1 + math.cos(math.pi * t))
        counts.append(max(cfg.window + 1, int(round(n * rate))))
    counts[0] = n
    return counts


def _select_topk_sorted(scores, k_keep):
    """Top-k indices sorted ascending (preserve causal token order).

    Implemented via argsort rather than ``jax.lax.top_k``: the latter
    lowers to the HLO ``topk`` op, whose text form the xla_extension
    0.5.1 parser cannot read; ``sort`` round-trips fine.
    """
    idx = jnp.argsort(-scores)[:k_keep]
    return jnp.sort(idx)


def _tsp_select(win, n_valid, nt, cfg: ModelConfig):
    """Eq. 1-2 selection inside HLO: head-mean, max-pool, always keep the
    observation window, take top-nt, sorted ascending."""
    s = jnp.mean(win, axis=0)                              # [N]
    s = maxpool1d_ref(s, cfg.pool_kernel)
    n = s.shape[0]
    idxs = jnp.arange(n)
    in_win = (idxs >= n_valid - cfg.window) & (idxs < n_valid)
    s = jnp.where(in_win, jnp.inf, s)
    s = jnp.where(idxs < n_valid, s, -jnp.inf)
    return _select_topk_sorted(s, nt)


def prefill_pyramid(flat, tokens, n_valid, *, cfg: ModelConfig,
                    min_rate: float = 0.6, kernel: str = "jnp"):
    """PyramidInfer-style prefill: each layer keeps only the top
    ``schedule[l]`` tokens (by its own window scores) for the next layer,
    *and its KV cache is whatever tokens it processed* (retention coupled
    to compute — the coupling FastKV removes).

    Returns (logits [V], k [L,N,KV,hd] zero-padded, v, lens [L] i32).
    """
    params = unflatten(flat, cfg)
    n = tokens.shape[0]
    schedule = pyramid_schedule(cfg, n, min_rate)
    positions = jnp.arange(n, dtype=jnp.int32)
    x = _embed(params, tokens)
    cur_n = n
    cur_valid = n_valid
    ks, vs, lens = [], [], []
    for i in range(cfg.n_layers):
        lp = L.layer_params(params, i)
        x, k, v, win, acc = L.decoder_layer(
            x, lp, cfg, positions, cur_valid, kernel
        )
        pad = n - cur_n
        ks.append(jnp.pad(k, ((0, pad), (0, 0), (0, 0))))
        vs.append(jnp.pad(v, ((0, pad), (0, 0), (0, 0))))
        lens.append(cur_valid)
        if i + 1 < cfg.n_layers and schedule[i + 1] < cur_n:
            nt = schedule[i + 1]
            sel = _tsp_select(win, cur_valid, nt, cfg)
            x = x[sel]
            positions = positions[sel]
            cur_valid = jnp.minimum(cur_valid, nt)
            cur_n = nt
    logits, _ = _final_logits_at(params, cfg, x, cur_valid - 1)
    return logits, jnp.stack(ks), jnp.stack(vs), jnp.stack(lens)


def decode_step(flat, tokens, positions, k_cache, v_cache, lens, *,
                cfg: ModelConfig):
    """Batched single-token decode.

    tokens [B] i32, positions [B] i32 (absolute), k/v_cache
    [L,B,C,KV,hd] (token-major, post-RoPE keys, slot ``lens[l,b]`` must be
    free — the new token is written there in-HLO for attention and also
    returned so rust can persist it), lens [L,B] i32 ->
    (logits [B,V], k_new [L,B,KV,hd], v_new [L,B,KV,hd])
    """
    params = unflatten(flat, cfg)
    b = tokens.shape[0]

    def one_seq(tok, pos, kc, vc, ln):
        # kc/vc: [L, C, KV, hd]; ln: [L]
        x = params["embed"][tok]
        k_news, v_news = [], []
        for i in range(cfg.n_layers):
            lp = L.layer_params(params, i)
            x, k_new, v_new = L.decode_layer_cached(
                x, lp, cfg, pos, kc[i], vc[i], ln[i]
            )
            k_news.append(k_new)
            v_news.append(v_new)
        h = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = h @ params["lm_head"]
        return logits, jnp.stack(k_news), jnp.stack(v_news)

    logits, k_new, v_new = jax.vmap(
        one_seq, in_axes=(0, 0, 1, 1, 1), out_axes=(0, 1, 1)
    )(tokens, positions, k_cache, v_cache, lens)
    return logits, k_new, v_new


def decode_paged_step(flat, tokens, positions, slab_k, slab_v, tables,
                      lens, *, cfg: ModelConfig):
    """Block-table (paged) batched single-token decode.

    tokens [B] i32, positions [B] i32 (absolute),
    slab_k/slab_v [NB, bt, KV, hd] — the shared block pool slab,
    tables [L, B, MB] i32 — physical block of each lane's i-th logical
    block (-1 past the table's end; MB = ceil(C / bt)),
    lens [L, B] i32 ->
    (logits [B,V], k_new [L,B,KV,hd], v_new [L,B,KV,hd])

    Each lane's cache is gathered from the slab through its block table
    (logical row r lives in block ``tables[l, b, r // bt]`` at row
    ``r % bt``), then attended exactly like ``decode_step``: columns past
    ``lens`` are masked, and the new token's K/V is written at slot
    ``lens`` in-HLO. Junk rows gathered through -1 / stale table entries
    are therefore never attended. Equivalence to ``decode_step`` is pinned
    by ``python/tests/test_model.py`` and, end to end against the rust
    staging layout, by ``rust/tests/paging.rs``.
    """
    params = unflatten(flat, cfg)
    nb = slab_k.shape[0]

    def one_seq(tok, pos, tbl, ln):
        # tbl: [L, MB]; ln: [L]
        x = params["embed"][tok]
        k_news, v_news = [], []
        for i in range(cfg.n_layers):
            lp = L.layer_params(params, i)
            idx = jnp.clip(tbl[i], 0, nb - 1)              # [MB]
            kc = slab_k[idx].reshape(-1, cfg.n_kv_heads, cfg.head_dim)
            vc = slab_v[idx].reshape(-1, cfg.n_kv_heads, cfg.head_dim)
            x, k_new, v_new = L.decode_layer_cached(
                x, lp, cfg, pos, kc, vc, ln[i]
            )
            k_news.append(k_new)
            v_news.append(v_new)
        h = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = h @ params["lm_head"]
        return logits, jnp.stack(k_news), jnp.stack(v_news)

    logits, k_new, v_new = jax.vmap(
        one_seq, in_axes=(0, 0, 1, 1), out_axes=(0, 1, 1)
    )(tokens, positions, tables, lens)
    return logits, k_new, v_new


def decode_paged_shard_step(flat, tokens, positions, *rest,
                            cfg: ModelConfig, shards: int):
    """KV-head-sharded block-table decode.

    ``rest`` is ``(slab_k_0, slab_v_0, ..., slab_k_{S-1}, slab_v_{S-1},
    tables, lens)``: each shard contributes its own slab pair of
    ``[NB, bt, KV/S, hd]`` (heads ``[s*KV/S, (s+1)*KV/S)`` of every row —
    device-pinned per shard on the rust side, so a mutation confined to
    one shard re-uploads only that shard's planes), while the block
    tables and lens are shard-oblivious and shared.

    KV heads are independent under GQA attention, so concatenating the
    shard slabs along the head axis reconstructs the full cache exactly
    and the math is ``decode_paged_step`` verbatim. Outputs are
    ``(logits [B,V], k_new_0 [L,B,KV/S,hd], v_new_0, ..., k_new_{S-1},
    v_new_{S-1})`` — each shard's slice of the new KV row, which the
    host-side combiner (rust ``coordinator::decode::combine_head_shards``)
    reassembles; equivalence to the unsharded artifact is pinned by
    ``python/tests/test_model.py``.
    """
    assert cfg.n_kv_heads % shards == 0, "shards must divide kv heads"
    slabs, tables, lens = rest[:2 * shards], rest[-2], rest[-1]
    slab_k = jnp.concatenate(slabs[0::2], axis=2)
    slab_v = jnp.concatenate(slabs[1::2], axis=2)
    logits, k_new, v_new = decode_paged_step(
        flat, tokens, positions, slab_k, slab_v, tables, lens, cfg=cfg
    )
    kvs = cfg.n_kv_heads // shards
    outs = [logits]
    for s in range(shards):
        outs.append(k_new[:, :, s * kvs:(s + 1) * kvs, :])
        outs.append(v_new[:, :, s * kvs:(s + 1) * kvs, :])
    return tuple(outs)


def decode_paged_q8_step(flat, tokens, positions, slab_kq, k_scales,
                         slab_vq, v_scales, tables, lens, *,
                         cfg: ModelConfig):
    """Int8-quantized block-table decode: dequantize in-HLO, then
    ``decode_paged_step`` verbatim.

    slab_kq/slab_vq [NB, bt, KV, hd] — the quantized slab planes. The
    runtime ABI is f32-only, so the int8 codes travel as integer-valued
    f32 in [-127, 127]; XLA folds the dequant multiply into the gather's
    consumers, so no widened copy of the slab persists.
    k_scales/v_scales [NB, bt] — one per-row scale per block row
    (``scale = max|row| / 127``, rust ``paging::codec``); zero rows carry
    scale 0, making the dequant exact there.

    The dequantized slab equals the rust host-side fallback
    (``BlockStore`` decode) bit for bit — both compute
    ``q * scale`` in f32 — so the q8 artifact and the host-dequant paged
    path agree exactly; equivalence is pinned by
    ``python/tests/test_model.py``.
    """
    slab_k = slab_kq * k_scales[:, :, None, None]
    slab_v = slab_vq * v_scales[:, :, None, None]
    return decode_paged_step(
        flat, tokens, positions, slab_k, slab_v, tables, lens, cfg=cfg
    )


def decode_paged_q8_shard_step(flat, tokens, positions, *rest,
                               cfg: ModelConfig, shards: int):
    """Sharded twin of ``decode_paged_q8_step``.

    ``rest`` is ``(slab_kq_0, k_scales_0, slab_vq_0, v_scales_0, ...,
    tables, lens)``: per shard, the quantized K/V planes of that shard's
    heads (``[NB, bt, KV/S, hd]``) each paired with per-row scales
    ``[NB, bt]``. Note the scales are per *full* row, shared by every
    shard of that row — quantization happened on the unsharded row, so
    all shards of one row dequantize under the same scale. Outputs match
    ``decode_paged_shard_step``.
    """
    assert cfg.n_kv_heads % shards == 0, "shards must divide kv heads"
    slabs, tables, lens = rest[:4 * shards], rest[-2], rest[-1]
    deq_k = [
        slabs[4 * s + 0] * slabs[4 * s + 1][:, :, None, None]
        for s in range(shards)
    ]
    deq_v = [
        slabs[4 * s + 2] * slabs[4 * s + 3][:, :, None, None]
        for s in range(shards)
    ]
    slab_k = jnp.concatenate(deq_k, axis=2)
    slab_v = jnp.concatenate(deq_v, axis=2)
    logits, k_new, v_new = decode_paged_step(
        flat, tokens, positions, slab_k, slab_v, tables, lens, cfg=cfg
    )
    kvs = cfg.n_kv_heads // shards
    outs = [logits]
    for s in range(shards):
        outs.append(k_new[:, :, s * kvs:(s + 1) * kvs, :])
        outs.append(v_new[:, :, s * kvs:(s + 1) * kvs, :])
    return tuple(outs)


def sweep_tsp(flat, tokens, n_valid, *, cfg: ModelConfig, t: int, nt: int,
              kernel: str = "jnp"):
    """Full model with TSP applied at layer ``t`` (selection inside HLO).

    Used for the Fig. 3 logit-distance curve and the Fig. 5(b)/Table 10
    TSP-layer ablations: one artifact per candidate layer.

    Returns (logits [V], final_h [D]).
    """
    params = unflatten(flat, cfg)
    n = tokens.shape[0]
    positions = jnp.arange(n, dtype=jnp.int32)
    x = _embed(params, tokens)
    cur_valid = n_valid
    for i in range(cfg.n_layers):
        lp = L.layer_params(params, i)
        x, k, v, win, acc = L.decoder_layer(
            x, lp, cfg, positions, cur_valid, kernel
        )
        if i == t - 1 and nt < x.shape[0]:
            sel = _tsp_select(win, cur_valid, nt, cfg)
            x = x[sel]
            positions = positions[sel]
            cur_valid = jnp.minimum(cur_valid, nt)
    logits, final_h = _final_logits_at(params, cfg, x, cur_valid - 1)
    return logits, final_h


def forward_train(flat, tokens, *, cfg: ModelConfig):
    """Training forward pass: batched full-context, returns logits for every
    position.  tokens [B, N] -> logits [B, N, V]."""
    params = unflatten(flat, cfg)

    def one(seq):
        n = seq.shape[0]
        positions = jnp.arange(n, dtype=jnp.int32)
        x = _embed(params, seq)
        nv = jnp.int32(n)
        for i in range(cfg.n_layers):
            lp = L.layer_params(params, i)
            x, *_ = L.decoder_layer(x, lp, cfg, positions, nv, "jnp")
        h = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return h @ params["lm_head"]

    return jax.vmap(one)(tokens)
